//! Inference backends behind the coordinator: the native bit-packed
//! engine, the cycle-accurate ASIC simulator and the PJRT-executed AOT
//! artifact — plus a mirror backend that cross-checks two of them on live
//! traffic (the paper's "ASIC matches SW exactly" property as a runtime
//! invariant).

use crate::asic::{Accelerator, ChipConfig};
use crate::data::boolean::BoolImage;
use crate::runtime::{ModelInputs, Runtime};
use crate::tm::{Engine, Model};
use anyhow::{anyhow, Result};
use std::path::Path;

/// One classification outcome from a backend.
#[derive(Clone, Debug, PartialEq)]
pub struct BackendOutput {
    pub prediction: u8,
    pub class_sums: Vec<i32>,
    /// Simulated accelerator cycles attributed to this image (ASIC backend
    /// only; None for purely functional backends).
    pub sim_cycles: Option<u64>,
}

/// A batched classification backend.
///
/// Not `Send`-bound: PJRT client handles are thread-affine, so the
/// coordinator constructs its backend *inside* the worker thread via a
/// `Send` factory (see `Coordinator::start_with`).
pub trait Backend {
    fn name(&self) -> &'static str;
    /// Largest batch the backend can consume in one call.
    fn max_batch(&self) -> usize;
    fn classify(&mut self, imgs: &[&BoolImage]) -> Result<Vec<BackendOutput>>;
}

impl<B: Backend + ?Sized> Backend for Box<B> {
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn max_batch(&self) -> usize {
        (**self).max_batch()
    }
    fn classify(&mut self, imgs: &[&BoolImage]) -> Result<Vec<BackendOutput>> {
        (**self).classify(imgs)
    }
}

/// The native Rust golden-model engine (SW baseline).
pub struct NativeBackend {
    model: Model,
    engine: Engine,
}

impl NativeBackend {
    pub fn new(model: Model) -> Self {
        NativeBackend {
            model,
            engine: Engine::new(),
        }
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn max_batch(&self) -> usize {
        64
    }

    fn classify(&mut self, imgs: &[&BoolImage]) -> Result<Vec<BackendOutput>> {
        Ok(imgs
            .iter()
            .map(|img| {
                let inf = self.engine.classify(&self.model, img);
                BackendOutput {
                    prediction: inf.prediction,
                    class_sums: inf.class_sums,
                    sim_cycles: None,
                }
            })
            .collect())
    }
}

/// The cycle-accurate ASIC simulator in continuous mode.
pub struct AsicBackend {
    acc: Accelerator,
    /// Whether the *previous* image in this backend's stream overlaps the
    /// transfer (true after the first image — double buffering, §IV-C).
    primed: bool,
}

impl AsicBackend {
    pub fn new(model: &Model, config: ChipConfig) -> Self {
        let mut acc = Accelerator::new(model.params.clone(), config);
        acc.load_model(model);
        AsicBackend { acc, primed: false }
    }

    pub fn accelerator(&self) -> &Accelerator {
        &self.acc
    }
}

impl Backend for AsicBackend {
    fn name(&self) -> &'static str {
        "asic-sim"
    }

    fn max_batch(&self) -> usize {
        64
    }

    fn classify(&mut self, imgs: &[&BoolImage]) -> Result<Vec<BackendOutput>> {
        let mut out = Vec::with_capacity(imgs.len());
        for img in imgs {
            let res = self.acc.classify(img, None, self.primed)?;
            self.primed = true;
            out.push(BackendOutput {
                prediction: res.prediction,
                class_sums: res.class_sums,
                sim_cycles: Some(res.report.phases.latency() as u64),
            });
        }
        Ok(out)
    }
}

/// The AOT artifact executed through PJRT (L2/L1 on the request path).
pub struct PjrtBackend {
    runtime: Runtime,
    inputs: ModelInputs,
    artifact: String,
    batch: usize,
}

impl PjrtBackend {
    pub fn new(artifact_dir: &Path, artifact: &str, batch: usize, model: &Model) -> Result<Self> {
        let mut runtime = Runtime::new(artifact_dir)?;
        runtime.load(artifact, batch)?; // compile eagerly
        Ok(PjrtBackend {
            runtime,
            inputs: ModelInputs::from_model(model),
            artifact: artifact.to_string(),
            batch,
        })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn classify(&mut self, imgs: &[&BoolImage]) -> Result<Vec<BackendOutput>> {
        let graph = self.runtime.load(&self.artifact, self.batch)?;
        let outs = graph.run(imgs, &self.inputs)?;
        Ok(outs
            .into_iter()
            .map(|o| BackendOutput {
                prediction: o.prediction,
                class_sums: o.class_sums.iter().map(|&x| x as i32).collect(),
                sim_cycles: None,
            })
            .collect())
    }
}

/// Runs a primary and a reference backend on the same traffic and fails
/// loudly on any divergence.
pub struct MirrorBackend {
    primary: Box<dyn Backend>,
    reference: Box<dyn Backend>,
    pub compared: u64,
}

impl MirrorBackend {
    pub fn new(primary: Box<dyn Backend>, reference: Box<dyn Backend>) -> Self {
        MirrorBackend {
            primary,
            reference,
            compared: 0,
        }
    }
}

impl Backend for MirrorBackend {
    fn name(&self) -> &'static str {
        "mirror"
    }

    fn max_batch(&self) -> usize {
        self.primary.max_batch().min(self.reference.max_batch())
    }

    fn classify(&mut self, imgs: &[&BoolImage]) -> Result<Vec<BackendOutput>> {
        let a = self.primary.classify(imgs)?;
        let b = self.reference.classify(imgs)?;
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            if x.prediction != y.prediction || x.class_sums != y.class_sums {
                return Err(anyhow!(
                    "backend divergence on image {i}: {}={:?} vs {}={:?}",
                    self.primary.name(),
                    (x.prediction, &x.class_sums),
                    self.reference.name(),
                    (y.prediction, &y.class_sums)
                ));
            }
        }
        self.compared += imgs.len() as u64;
        Ok(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::Params;
    use crate::util::Xoshiro256ss;

    pub(crate) fn random_model(seed: u64) -> Model {
        let params = Params::asic();
        let mut rng = Xoshiro256ss::new(seed);
        let mut m = Model::blank(params.clone());
        for j in 0..params.clauses {
            for _ in 0..1 + rng.usize_below(5) {
                m.set_include(j, rng.usize_below(params.literals), true);
            }
            for i in 0..params.classes {
                m.set_weight(i, j, (rng.below(61) as i32 - 30) as i8);
            }
        }
        m
    }

    pub(crate) fn random_images(seed: u64, n: usize) -> Vec<BoolImage> {
        let mut rng = Xoshiro256ss::new(seed);
        (0..n)
            .map(|_| {
                BoolImage::from_bools(&(0..784).map(|_| rng.chance(0.3)).collect::<Vec<_>>())
            })
            .collect()
    }

    #[test]
    fn native_and_asic_agree() {
        let model = random_model(1);
        let imgs = random_images(2, 6);
        let refs: Vec<&BoolImage> = imgs.iter().collect();
        let mut native = NativeBackend::new(model.clone());
        let mut asic = AsicBackend::new(&model, ChipConfig::default());
        let a = native.classify(&refs).unwrap();
        let b = asic.classify(&refs).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prediction, y.prediction);
            assert_eq!(x.class_sums, y.class_sums);
        }
        // ASIC backend reports cycles: first image 471, then 372.
        assert_eq!(b[0].sim_cycles, Some(471));
        assert_eq!(b[1].sim_cycles, Some(372));
    }

    #[test]
    fn mirror_passes_on_agreement() {
        let model = random_model(3);
        let imgs = random_images(4, 5);
        let refs: Vec<&BoolImage> = imgs.iter().collect();
        let mut mirror = MirrorBackend::new(
            Box::new(NativeBackend::new(model.clone())),
            Box::new(AsicBackend::new(&model, ChipConfig::default())),
        );
        let out = mirror.classify(&refs).unwrap();
        assert_eq!(out.len(), 5);
        assert_eq!(mirror.compared, 5);
    }

    #[test]
    fn mirror_detects_divergence() {
        let model_a = random_model(5);
        let model_b = random_model(6); // different model → different sums
        let imgs = random_images(7, 3);
        let refs: Vec<&BoolImage> = imgs.iter().collect();
        let mut mirror = MirrorBackend::new(
            Box::new(NativeBackend::new(model_a)),
            Box::new(NativeBackend::new(model_b)),
        );
        assert!(mirror.classify(&refs).is_err());
    }
}
