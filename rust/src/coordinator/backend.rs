//! Inference backends behind the coordinator: the native bit-packed
//! engine, the cycle-accurate ASIC simulator and the PJRT-executed AOT
//! artifact (feature `pjrt`) — plus a mirror backend that cross-checks two
//! of them on live traffic (the paper's "ASIC matches SW exactly" property
//! as a runtime invariant).
//!
//! Every backend validates request geometry against its loaded model: a
//! 32×32 request against a 28×28 model is rejected as an error instead of
//! panicking deep inside patch generation.

use crate::asic::{Accelerator, ChipConfig};
use crate::data::boolean::BoolImage;
use crate::data::Geometry;
use crate::obs::StageTiming;
use crate::tm::{BlockEval, ClausePlan, EvalScratch, Model, DEFAULT_BLOCK, MIN_BLOCK};
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// One classification outcome from a backend.
#[derive(Clone, Debug, PartialEq)]
pub struct BackendOutput {
    pub prediction: u8,
    pub class_sums: Vec<i32>,
    /// Simulated accelerator cycles attributed to this image (ASIC backend
    /// only; None for purely functional backends).
    pub sim_cycles: Option<u64>,
    /// Registry version of the model that served this request (pool mode
    /// only; None for anonymous single-backend serving). Carried to the
    /// network edge so clients can prove which deploy answered them — the
    /// invariant the hot-swap tests pin is "prediction and version always
    /// agree".
    pub model_version: Option<u64>,
    /// Coordinator-side stage split (queue wait / eval, and whether the
    /// blocked evaluator served the request), measured by the shard
    /// worker that owns the clocks and carried back in-band so the HTTP
    /// thread can assemble the request's span tree without cross-thread
    /// trace plumbing. `None` from plain backends (they never see the
    /// queue), and always `None` in backend unit tests — full-struct
    /// equality there stays meaningful.
    pub timing: Option<StageTiming>,
}

/// A batched classification backend.
///
/// Not `Send`-bound: PJRT client handles are thread-affine, so the
/// coordinator constructs its backend *inside* the worker thread via a
/// `Send` factory (see `Coordinator::start_with`).
pub trait Backend {
    fn name(&self) -> &'static str;
    /// Largest batch the backend can consume in one call.
    fn max_batch(&self) -> usize;
    /// The patch geometry this backend serves (requests must match).
    fn geometry(&self) -> Geometry;
    fn classify(&mut self, imgs: &[&BoolImage]) -> Result<Vec<BackendOutput>>;
}

impl<B: Backend + ?Sized> Backend for Box<B> {
    fn name(&self) -> &'static str {
        (**self).name()
    }
    fn max_batch(&self) -> usize {
        (**self).max_batch()
    }
    fn geometry(&self) -> Geometry {
        (**self).geometry()
    }
    fn classify(&mut self, imgs: &[&BoolImage]) -> Result<Vec<BackendOutput>> {
        (**self).classify(imgs)
    }
}

/// Reject images whose side does not match the model's geometry. The
/// error is a typed [`BadGeometry`] so the HTTP layer can downcast it
/// into its `bad_geometry` code. It stays the *outermost* error (no
/// context wrapper): the typed Display carries the sizes, which callers
/// and tests match on.
fn validate_geometry(_name: &str, g: Geometry, imgs: &[&BoolImage]) -> Result<()> {
    for img in imgs {
        if img.side() != g.img_side {
            return Err(anyhow::Error::new(super::BadGeometry {
                model: None,
                side: img.side(),
                expected_side: g.img_side,
                geometry: g.to_string(),
            }));
        }
    }
    Ok(())
}

/// The native Rust golden-model engine (SW baseline). The model is
/// compiled once into a [`ClausePlan`] (sparse ordered include lists +
/// clause-major weights) plus its image-major [`BlockEval`] twin; batches
/// of ≥ [`MIN_BLOCK`] images route through the blocked bit-sliced path
/// (each clause row processed once per block of [`DEFAULT_BLOCK`] images),
/// smaller runs stay per-image. Every worker evaluates through a reusable
/// [`EvalScratch`] arena, so the *evaluation step* is allocation-free in
/// both modes (constructing each `BackendOutput` still allocates its
/// class-sums Vec — that is the serving API's cost, not the evaluator's;
/// [`Self::classify_block`] exposes the allocation-free core directly).
/// Batches are classified in parallel across worker threads (scoped;
/// images are independent), which is what lets the coordinator's dynamic
/// batching use more than one core.
pub struct NativeBackend {
    model: Arc<Model>,
    plan: Arc<ClausePlan>,
    /// Image-major compiled twin of `plan` (`tm::block`).
    block: Arc<BlockEval>,
    threads: usize,
    /// Serial-path arena.
    scratch: EvalScratch,
    /// Parallel-path arenas, one per worker, persisted across batches so
    /// the per-batch scoped threads re-use warm patch-set tables.
    worker_scratch: Vec<EvalScratch>,
    /// Debug-only: blocked vs scalar cross-check ran on the first batch.
    #[cfg(debug_assertions)]
    cross_checked: bool,
}

/// Classify one image through the compiled plan + arena.
fn plan_classify_one(
    plan: &ClausePlan,
    img: &BoolImage,
    scratch: &mut EvalScratch,
) -> BackendOutput {
    let prediction = plan.classify_into(img, scratch);
    BackendOutput {
        prediction,
        class_sums: scratch.class_sums().to_vec(),
        sim_cycles: None,
        model_version: None,
        timing: None,
    }
}

impl NativeBackend {
    pub fn new(model: Model) -> Self {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        Self::with_threads(model, threads)
    }

    /// Explicit worker-thread cap (1 = serial; used by benches and the
    /// CLI's `--threads` flag to measure the batch-parallel speedup).
    pub fn with_threads(model: Model, threads: usize) -> Self {
        let plan = Arc::new(ClausePlan::compile(&model));
        Self::from_shared_plan(Arc::new(model), plan, threads)
    }

    /// Build from an already-compiled shared plan — e.g. a registry
    /// [`crate::coordinator::ModelEntry`]'s — so N backends over the same
    /// model pay for one compilation, not N (the shard pool's sharing
    /// contract, here available to trait-object serving too). The blocked
    /// twin is derived from the plan here (cheap relative to plan
    /// compilation: a CSR copy plus op extraction).
    pub fn from_shared_plan(model: Arc<Model>, plan: Arc<ClausePlan>, threads: usize) -> Self {
        let block = Arc::new(BlockEval::compile(&plan));
        NativeBackend {
            model,
            plan,
            block,
            threads: threads.max(1),
            scratch: EvalScratch::new(),
            worker_scratch: Vec::new(),
            #[cfg(debug_assertions)]
            cross_checked: false,
        }
    }

    /// The allocation-free blocked core: classify the whole batch through
    /// the image-major path into the internal arena and return the
    /// predictions (per-image class sums stay readable via
    /// `scratch.block()`; this is the path the hot-path bench measures at
    /// 0.0 allocs/image). The trait's [`Backend::classify`] routes through
    /// the same evaluator and then materializes owned `BackendOutput`s.
    pub fn classify_block(&mut self, imgs: &[&BoolImage]) -> Result<&[u8]> {
        validate_geometry("native", self.model.params.geometry, imgs)?;
        self.block
            .classify_block_into(imgs, DEFAULT_BLOCK, &mut self.scratch.block);
        Ok(self.scratch.block().predictions())
    }

    /// Debug builds cross-check the blocked path against the scalar plan
    /// on the first sufficiently large batch this backend serves — the
    /// serial ≡ blocked invariant as a runtime assertion (mirrors the
    /// shard pool's post-hot-swap check).
    #[cfg(debug_assertions)]
    fn cross_check_first_batch(&mut self, imgs: &[&BoolImage]) {
        if self.cross_checked || imgs.len() < MIN_BLOCK {
            return;
        }
        self.cross_checked = true;
        let NativeBackend {
            plan,
            block,
            scratch,
            ..
        } = self;
        block.classify_block_into(imgs, DEFAULT_BLOCK, &mut scratch.block);
        for (i, img) in imgs.iter().enumerate() {
            let blocked_pred = scratch.block.predictions()[i];
            let scalar_pred = plan.classify_into(img, scratch);
            debug_assert_eq!(
                blocked_pred, scalar_pred,
                "blocked vs scalar prediction divergence on image {i}"
            );
            debug_assert_eq!(
                scratch.block.class_sums(i),
                scratch.class_sums(),
                "blocked vs scalar class-sum divergence on image {i}"
            );
        }
    }
}

/// Materialize the blocked arena's results for `n` images as owned
/// backend outputs (the serving API's per-image allocation).
fn block_outputs(scratch: &EvalScratch, n: usize) -> Vec<BackendOutput> {
    let block = scratch.block();
    (0..n)
        .map(|i| BackendOutput {
            prediction: block.predictions()[i],
            class_sums: block.class_sums(i).to_vec(),
            sim_cycles: None,
            model_version: None,
            timing: None,
        })
        .collect()
}

/// Classify one worker's chunk: blocked when large enough to amortize the
/// per-block transpose + screen build, scalar otherwise.
fn classify_chunk(
    plan: &ClausePlan,
    block: &BlockEval,
    part: &[&BoolImage],
    scratch: &mut EvalScratch,
) -> Vec<BackendOutput> {
    if part.len() >= MIN_BLOCK {
        block.classify_block_into(part, DEFAULT_BLOCK, &mut scratch.block);
        block_outputs(scratch, part.len())
    } else {
        part.iter()
            .map(|img| plan_classify_one(plan, img, scratch))
            .collect()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn max_batch(&self) -> usize {
        64
    }

    fn geometry(&self) -> Geometry {
        self.model.params.geometry
    }

    fn classify(&mut self, imgs: &[&BoolImage]) -> Result<Vec<BackendOutput>> {
        validate_geometry(self.name(), self.geometry(), imgs)?;
        #[cfg(debug_assertions)]
        self.cross_check_first_batch(imgs);
        let threads = self.threads.min(imgs.len());
        // Scoped threads are spawned per batch; below this size the spawn
        // cost exceeds the ~µs-scale per-image engine work, so stay serial.
        const MIN_PARALLEL_BATCH: usize = 8;
        if threads <= 1 || imgs.len() < MIN_PARALLEL_BATCH {
            let NativeBackend {
                plan,
                block,
                scratch,
                ..
            } = self;
            return Ok(classify_chunk(plan, block, imgs, scratch));
        }
        // Chunk the batch across scoped threads; the plans are shared
        // read-only, each worker borrows its persistent arena for the
        // whole chunk and evaluates it blocked when large enough.
        if self.worker_scratch.len() < threads {
            self.worker_scratch.resize_with(threads, EvalScratch::new);
        }
        let chunk = imgs.len().div_ceil(threads);
        let plan = &self.plan;
        let block = &self.block;
        let outputs = std::thread::scope(|s| {
            let handles: Vec<_> = imgs
                .chunks(chunk)
                .zip(self.worker_scratch.iter_mut())
                .map(|(part, scratch)| {
                    s.spawn(move || classify_chunk(plan, block, part, scratch))
                })
                .collect();
            handles
                .into_iter()
                .flat_map(|h| h.join().expect("batch worker panicked"))
                .collect::<Vec<_>>()
        });
        Ok(outputs)
    }
}

/// The cycle-accurate ASIC simulator in continuous mode.
pub struct AsicBackend {
    acc: Accelerator,
    /// Whether the *previous* image in this backend's stream overlaps the
    /// transfer (true after the first image — double buffering, §IV-C).
    primed: bool,
}

impl AsicBackend {
    pub fn new(model: &Model, config: ChipConfig) -> Self {
        let mut acc = Accelerator::new(model.params.clone(), config);
        acc.load_model(model);
        AsicBackend { acc, primed: false }
    }

    pub fn accelerator(&self) -> &Accelerator {
        &self.acc
    }
}

impl Backend for AsicBackend {
    fn name(&self) -> &'static str {
        "asic-sim"
    }

    fn max_batch(&self) -> usize {
        64
    }

    fn geometry(&self) -> Geometry {
        self.acc
            .model()
            .map(|m| m.params.geometry)
            .unwrap_or_default()
    }

    fn classify(&mut self, imgs: &[&BoolImage]) -> Result<Vec<BackendOutput>> {
        validate_geometry(self.name(), self.geometry(), imgs)?;
        let mut out = Vec::with_capacity(imgs.len());
        for img in imgs {
            let res = self.acc.classify(img, None, self.primed)?;
            self.primed = true;
            out.push(BackendOutput {
                prediction: res.prediction,
                class_sums: res.class_sums,
                sim_cycles: Some(res.report.phases.latency() as u64),
                model_version: None,
                timing: None,
            });
        }
        Ok(out)
    }
}

/// The AOT artifact executed through PJRT (L2/L1 on the request path).
/// The compiled graphs are fixed to the ASIC geometry.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    runtime: crate::runtime::Runtime,
    inputs: crate::runtime::ModelInputs,
    artifact: String,
    batch: usize,
}

#[cfg(feature = "pjrt")]
impl PjrtBackend {
    pub fn new(
        artifact_dir: &std::path::Path,
        artifact: &str,
        batch: usize,
        model: &Model,
    ) -> Result<Self> {
        anyhow::ensure!(
            model.params.geometry == Geometry::asic(),
            "PJRT artifacts are compiled for the ASIC geometry, model has {}",
            model.params.geometry
        );
        let mut runtime = crate::runtime::Runtime::new(artifact_dir)?;
        runtime.load(artifact, batch)?; // compile eagerly
        Ok(PjrtBackend {
            runtime,
            inputs: crate::runtime::ModelInputs::from_model(model),
            artifact: artifact.to_string(),
            batch,
        })
    }
}

#[cfg(feature = "pjrt")]
impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn geometry(&self) -> Geometry {
        Geometry::asic()
    }

    fn classify(&mut self, imgs: &[&BoolImage]) -> Result<Vec<BackendOutput>> {
        validate_geometry(self.name(), self.geometry(), imgs)?;
        let graph = self.runtime.load(&self.artifact, self.batch)?;
        let outs = graph.run(imgs, &self.inputs)?;
        Ok(outs
            .into_iter()
            .map(|o| BackendOutput {
                prediction: o.prediction,
                class_sums: o.class_sums.iter().map(|&x| x as i32).collect(),
                sim_cycles: None,
                model_version: None,
                timing: None,
            })
            .collect())
    }
}

/// Runs a primary and a reference backend on the same traffic and fails
/// loudly on any divergence.
pub struct MirrorBackend {
    primary: Box<dyn Backend>,
    reference: Box<dyn Backend>,
    pub compared: u64,
}

impl MirrorBackend {
    pub fn new(primary: Box<dyn Backend>, reference: Box<dyn Backend>) -> Self {
        MirrorBackend {
            primary,
            reference,
            compared: 0,
        }
    }
}

impl Backend for MirrorBackend {
    fn name(&self) -> &'static str {
        "mirror"
    }

    fn max_batch(&self) -> usize {
        self.primary.max_batch().min(self.reference.max_batch())
    }

    fn geometry(&self) -> Geometry {
        self.primary.geometry()
    }

    fn classify(&mut self, imgs: &[&BoolImage]) -> Result<Vec<BackendOutput>> {
        let a = self.primary.classify(imgs)?;
        let b = self.reference.classify(imgs)?;
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            if x.prediction != y.prediction || x.class_sums != y.class_sums {
                return Err(anyhow!(
                    "backend divergence on image {i}: {}={:?} vs {}={:?}",
                    self.primary.name(),
                    (x.prediction, &x.class_sums),
                    self.reference.name(),
                    (y.prediction, &y.class_sums)
                ));
            }
        }
        self.compared += imgs.len() as u64;
        Ok(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::Params;
    use crate::util::Xoshiro256ss;

    pub(crate) fn random_model(seed: u64) -> Model {
        let params = Params::asic();
        let mut rng = Xoshiro256ss::new(seed);
        let mut m = Model::blank(params.clone());
        for j in 0..params.clauses {
            for _ in 0..1 + rng.usize_below(5) {
                m.set_include(j, rng.usize_below(params.literals), true);
            }
            for i in 0..params.classes {
                m.set_weight(i, j, (rng.below(61) as i32 - 30) as i8);
            }
        }
        m
    }

    pub(crate) fn random_images(seed: u64, n: usize) -> Vec<BoolImage> {
        let mut rng = Xoshiro256ss::new(seed);
        (0..n)
            .map(|_| {
                BoolImage::from_bools(&(0..784).map(|_| rng.chance(0.3)).collect::<Vec<_>>())
            })
            .collect()
    }

    #[test]
    fn native_and_asic_agree() {
        let model = random_model(1);
        let imgs = random_images(2, 6);
        let refs: Vec<&BoolImage> = imgs.iter().collect();
        let mut native = NativeBackend::new(model.clone());
        let mut asic = AsicBackend::new(&model, ChipConfig::default());
        let a = native.classify(&refs).unwrap();
        let b = asic.classify(&refs).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prediction, y.prediction);
            assert_eq!(x.class_sums, y.class_sums);
        }
        // ASIC backend reports cycles: first image 471, then 372.
        assert_eq!(b[0].sim_cycles, Some(471));
        assert_eq!(b[1].sim_cycles, Some(372));
    }

    #[test]
    fn parallel_native_matches_serial() {
        let model = random_model(2);
        let imgs = random_images(3, 24);
        let refs: Vec<&BoolImage> = imgs.iter().collect();
        let mut serial = NativeBackend::with_threads(model.clone(), 1);
        let mut parallel = NativeBackend::with_threads(model, 4);
        assert_eq!(
            serial.classify(&refs).unwrap(),
            parallel.classify(&refs).unwrap(),
            "batch parallelism must not change results or order"
        );
    }

    #[test]
    fn blocked_batches_match_per_image_classification() {
        let model = random_model(8);
        let imgs = random_images(9, 50);
        let refs: Vec<&BoolImage> = imgs.iter().collect();
        let mut backend = NativeBackend::with_threads(model, 1);
        // Large serial batch routes through the blocked path…
        let batched = backend.classify(&refs).unwrap();
        // …while single-image calls stay scalar (below MIN_BLOCK): both
        // must produce identical outputs.
        for (i, img) in refs.iter().enumerate() {
            let single = backend.classify(&[img]).unwrap();
            assert_eq!(single[0], batched[i], "image {i}");
        }
        // The allocation-free core agrees with the trait surface.
        let preds = backend.classify_block(&refs).unwrap().to_vec();
        for (i, out) in batched.iter().enumerate() {
            assert_eq!(preds[i], out.prediction, "image {i}");
        }
    }

    #[test]
    fn geometry_mismatch_is_an_error_not_a_panic() {
        let model = random_model(4); // 28×28 model
        let wrong = BoolImage::blank_sized(32);
        let right = BoolImage::blank();
        let refs: Vec<&BoolImage> = vec![&right, &wrong];
        let mut native = NativeBackend::new(model.clone());
        let err = native.classify(&refs).unwrap_err();
        assert!(err.to_string().contains("32x32"), "{err}");
        let mut asic = AsicBackend::new(&model, ChipConfig::default());
        assert!(asic.classify(&refs).is_err());
        // Matching geometry still classifies.
        assert_eq!(native.classify(&[&right]).unwrap().len(), 1);
    }

    #[test]
    fn mirror_passes_on_agreement() {
        let model = random_model(3);
        let imgs = random_images(4, 5);
        let refs: Vec<&BoolImage> = imgs.iter().collect();
        let mut mirror = MirrorBackend::new(
            Box::new(NativeBackend::new(model.clone())),
            Box::new(AsicBackend::new(&model, ChipConfig::default())),
        );
        let out = mirror.classify(&refs).unwrap();
        assert_eq!(out.len(), 5);
        assert_eq!(mirror.compared, 5);
        assert_eq!(mirror.geometry(), Geometry::asic());
    }

    #[test]
    fn mirror_detects_divergence() {
        let model_a = random_model(5);
        let model_b = random_model(6); // different model → different sums
        let imgs = random_images(7, 3);
        let refs: Vec<&BoolImage> = imgs.iter().collect();
        let mut mirror = MirrorBackend::new(
            Box::new(NativeBackend::new(model_a)),
            Box::new(NativeBackend::new(model_b)),
        );
        assert!(mirror.classify(&refs).is_err());
    }
}
