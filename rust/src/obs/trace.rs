//! Request-scoped tracing with a zero-allocation disarmed hot path.
//!
//! A [`TraceId`] is minted at the front door (or adopted from an inbound
//! `X-Request-Id` header after validation/truncation), echoed on every
//! response, propagated by the router to replicas over the same header,
//! and threaded through `Coordinator` submission — one id follows a
//! request across processes. While a request is being handled, the worker
//! thread holds an *active request scope* (fixed-size, stack-friendly)
//! into which stage timings are recorded: `parse`, `queue_wait`, `eval`
//! (scalar vs block path tagged), `serialize`, and on the router
//! `forward`/`failover`.
//!
//! Arming follows the `util::fault` discipline: the layer is compiled in
//! always and **disarmed by default** — [`record_stage`] and
//! [`end_request`] start with one relaxed atomic load and return without
//! touching a ring, a lock or the allocator. When armed (CLI `serve`/
//! `route` arm at startup; tests use the [`arm`] guard, which also holds
//! the process-wide arm lock), completed traces land in per-thread
//! bounded ring buffers plus a global ring of the [`SLOW_RING_CAP`] worst
//! requests over the armed threshold — the span trees `/v1/debug/slow`
//! serves.

use std::cell::{OnceCell, RefCell};
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// Longest request id we store or echo (a minted id is exactly this long:
/// 128 bits as 32 hex chars). Longer inbound ids are truncated here.
pub const TRACE_ID_MAX_LEN: usize = 32;

/// Per-thread ring capacity (completed traces retained per worker).
pub const RING_CAP: usize = 128;

/// Worst-request ring capacity (the `/v1/debug/slow` surface).
pub const SLOW_RING_CAP: usize = 64;

/// Most stages one request can record; later stages are dropped silently
/// (a trace is diagnostics, never an error source).
pub const MAX_STAGES: usize = 8;

/// A request id: inline bytes, `Copy`, no heap. Minted ids are 32
/// lowercase hex chars; adopted ids keep the client's bytes verbatim
/// (validated charset, truncated to [`TRACE_ID_MAX_LEN`]).
#[derive(Clone, Copy, PartialEq, Eq)]
pub struct TraceId {
    len: u8,
    bytes: [u8; TRACE_ID_MAX_LEN],
}

impl TraceId {
    /// The absent id (no active request).
    pub const NONE: TraceId = TraceId {
        len: 0,
        bytes: [0; TRACE_ID_MAX_LEN],
    };

    pub fn is_none(&self) -> bool {
        self.len == 0
    }

    pub fn as_str(&self) -> &str {
        std::str::from_utf8(&self.bytes[..self.len as usize]).unwrap_or("")
    }

    /// Mint a fresh 128-bit id: a per-process random seed (wall clock ×
    /// pid, mixed) combined with a relaxed counter, formatted as 32 hex
    /// chars. No allocation, no locks.
    pub fn mint() -> TraceId {
        static COUNTER: AtomicU64 = AtomicU64::new(0);
        static SEED: OnceLock<u64> = OnceLock::new();
        let seed = *SEED.get_or_init(|| {
            let t = SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_nanos() as u64)
                .unwrap_or(0);
            mix64(t ^ (u64::from(std::process::id())).rotate_left(32))
        });
        let c = COUNTER.fetch_add(1, Ordering::Relaxed);
        let hi = mix64(seed ^ c);
        let lo = mix64(hi ^ c.rotate_left(17) ^ seed.rotate_left(7));
        let mut id = TraceId {
            len: TRACE_ID_MAX_LEN as u8,
            bytes: [0; TRACE_ID_MAX_LEN],
        };
        const HEX: &[u8; 16] = b"0123456789abcdef";
        for i in 0..16 {
            id.bytes[i] = HEX[((hi >> (60 - 4 * i)) & 0xF) as usize];
            id.bytes[16 + i] = HEX[((lo >> (60 - 4 * i)) & 0xF) as usize];
        }
        id
    }

    /// Adopt an inbound `X-Request-Id` value. Accepted charset is
    /// `[0-9A-Za-z_-]`; anything else (or an empty value) returns `None`
    /// and the caller mints instead. Values longer than
    /// [`TRACE_ID_MAX_LEN`] bytes are truncated, not rejected.
    pub fn parse(raw: &str) -> Option<TraceId> {
        let raw = raw.trim();
        if raw.is_empty()
            || !raw
                .bytes()
                .all(|b| b.is_ascii_alphanumeric() || b == b'-' || b == b'_')
        {
            return None;
        }
        let take = raw.len().min(TRACE_ID_MAX_LEN);
        let mut id = TraceId {
            len: take as u8,
            bytes: [0; TRACE_ID_MAX_LEN],
        };
        id.bytes[..take].copy_from_slice(&raw.as_bytes()[..take]);
        Some(id)
    }
}

impl fmt::Display for TraceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

impl fmt::Debug for TraceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "TraceId({})", self.as_str())
    }
}

/// SplitMix64 finalizer (self-contained; no PRNG state needed).
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// The span taxonomy (DESIGN.md §14). One request records a subset of
/// these, in completion order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// HTTP request parsing (front-door event loop).
    Parse,
    /// Admission → shard-worker pickup, measured in the coordinator.
    QueueWait,
    /// Clause evaluation (scalar vs block path tagged via `blocked`).
    Eval,
    /// Response serialization in the server worker.
    Serialize,
    /// Router → replica exchange (the chosen owner).
    Forward,
    /// Router failover ladder after the preferred replica failed.
    Failover,
}

impl Stage {
    pub fn name(self) -> &'static str {
        match self {
            Stage::Parse => "parse",
            Stage::QueueWait => "queue_wait",
            Stage::Eval => "eval",
            Stage::Serialize => "serialize",
            Stage::Forward => "forward",
            Stage::Failover => "failover",
        }
    }
}

/// One recorded stage of a trace.
#[derive(Clone, Copy, Debug)]
pub struct StageRec {
    pub stage: Stage,
    /// Start offset from the request's admission, µs.
    pub offset_us: f64,
    pub dur_us: f64,
    /// Eval-path tag: true when the image-major blocked evaluator served
    /// the stage (meaningful for [`Stage::Eval`] only).
    pub blocked: bool,
}

impl Default for StageRec {
    fn default() -> Self {
        StageRec {
            stage: Stage::Parse,
            offset_us: 0.0,
            dur_us: 0.0,
            blocked: false,
        }
    }
}

/// Coordinator-side stage timing carried back to the front door on each
/// `BackendOutput`, so the server worker can assemble the full span tree
/// without cross-thread trace plumbing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StageTiming {
    /// Admission → worker pickup, µs.
    pub queue_wait_us: f64,
    /// Pickup → evaluation complete, µs.
    pub eval_us: f64,
    /// True when the blocked (image-major) evaluator served the request.
    pub blocked: bool,
}

/// A finished request's span tree: fixed-size and `Copy`, so ring
/// recording never allocates.
#[derive(Clone, Copy, Debug)]
pub struct CompletedTrace {
    pub id: TraceId,
    /// Wall-clock completion time, ms since the Unix epoch.
    pub unix_ms: u64,
    pub total_us: f64,
    pub status: u16,
    n_stages: u8,
    stages: [StageRec; MAX_STAGES],
}

impl CompletedTrace {
    pub fn stages(&self) -> &[StageRec] {
        &self.stages[..self.n_stages as usize]
    }

    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        let stages = Json::arr(self.stages().iter().map(|s| {
            let mut pairs = vec![
                ("stage", Json::str(s.stage.name())),
                ("offset_us", Json::num(s.offset_us)),
                ("dur_us", Json::num(s.dur_us)),
            ];
            if s.stage == Stage::Eval {
                pairs.push(("path", Json::str(if s.blocked { "block" } else { "scalar" })));
            }
            Json::obj(pairs)
        }));
        Json::obj([
            ("request_id", Json::str(self.id.as_str())),
            ("unix_ms", Json::num(self.unix_ms as f64)),
            ("status", Json::num(self.status as f64)),
            ("total_us", Json::num(self.total_us)),
            ("stages", stages),
        ])
    }
}

/// The in-flight request scope (thread-local, fixed size).
struct Active {
    id: TraceId,
    start: Instant,
    n: u8,
    stages: [StageRec; MAX_STAGES],
}

thread_local! {
    static ACTIVE: RefCell<Option<Active>> = const { RefCell::new(None) };
    static RING: OnceCell<Arc<Mutex<Ring>>> = const { OnceCell::new() };
}

struct Ring {
    entries: Vec<CompletedTrace>,
    next: usize,
}

static ARMED: AtomicBool = AtomicBool::new(false);
/// Completed requests at or above this total duration (µs) are candidates
/// for the slow ring. Stored as integer µs so the armed check stays one
/// relaxed load.
static SLOW_THRESHOLD_US: AtomicU64 = AtomicU64::new(0);
static RINGS: Mutex<Vec<Arc<Mutex<Ring>>>> = Mutex::new(Vec::new());
static SLOW: Mutex<Vec<CompletedTrace>> = Mutex::new(Vec::new());
/// Serializes armers (process-wide state), exactly like `util::fault`.
static ARM_LOCK: Mutex<()> = Mutex::new(());

/// True when span recording is armed. The only check on the hot path.
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Guard from [`arm`]: disarms on drop.
pub struct TraceGuard {
    _lock: MutexGuard<'static, ()>,
}

impl Drop for TraceGuard {
    fn drop(&mut self) {
        ARMED.store(false, Ordering::SeqCst);
    }
}

/// Arm span recording for the guard's lifetime (tests). Clears the rings
/// so assertions observe only the guarded window; holds the process-wide
/// arm lock so concurrent tests serialize.
#[must_use = "tracing disarms when the guard drops"]
pub fn arm(slow_threshold_us: u64) -> TraceGuard {
    let lock = ARM_LOCK.lock().unwrap_or_else(|p| p.into_inner());
    SLOW.lock().unwrap_or_else(|p| p.into_inner()).clear();
    for ring in RINGS.lock().unwrap_or_else(|p| p.into_inner()).iter() {
        let mut g = ring.lock().unwrap_or_else(|p| p.into_inner());
        g.entries.clear();
        g.next = 0;
    }
    SLOW_THRESHOLD_US.store(slow_threshold_us, Ordering::SeqCst);
    ARMED.store(true, Ordering::SeqCst);
    TraceGuard { _lock: lock }
}

/// Arm for the rest of the process (the CLI path — never disarms).
pub fn arm_process(slow_threshold_us: u64) {
    std::mem::forget(arm(slow_threshold_us));
}

/// Open a request scope on the current thread. Always maintained (the id
/// feeds the response echo, coordinator submission and log stamping);
/// span-recording work happens only when armed. Zero allocations.
pub fn begin_request(id: TraceId) {
    ACTIVE.with(|a| {
        *a.borrow_mut() = Some(Active {
            id,
            start: Instant::now(),
            n: 0,
            stages: [StageRec::default(); MAX_STAGES],
        });
    });
}

/// The current thread's active request id ([`TraceId::NONE`] outside a
/// request scope). Zero allocations.
pub fn current_trace() -> TraceId {
    ACTIVE.with(|a| a.borrow().as_ref().map(|x| x.id).unwrap_or(TraceId::NONE))
}

/// Elapsed µs since the current request scope opened (`0.0` outside a
/// scope) — the anchor for placing externally-measured stages
/// ([`StageTiming`]) on the request's timeline.
pub fn elapsed_us() -> f64 {
    ACTIVE.with(|a| {
        a.borrow()
            .as_ref()
            .map(|x| x.start.elapsed().as_secs_f64() * 1e6)
            .unwrap_or(0.0)
    })
}

/// Record a stage that ended now and lasted `dur_us`. One relaxed load
/// and an early return when disarmed.
#[inline]
pub fn record_stage(stage: Stage, dur_us: f64) {
    if !armed() {
        return;
    }
    ACTIVE.with(|a| {
        if let Some(x) = a.borrow_mut().as_mut() {
            let end_us = x.start.elapsed().as_secs_f64() * 1e6;
            push_stage(x, stage, (end_us - dur_us).max(0.0), dur_us, false);
        }
    });
}

/// Record a stage at an explicit offset from request admission — used for
/// coordinator timings ([`StageTiming`]) that were measured on a shard
/// worker thread and carried back with the response.
#[inline]
pub fn record_stage_at(stage: Stage, offset_us: f64, dur_us: f64, blocked: bool) {
    if !armed() {
        return;
    }
    ACTIVE.with(|a| {
        if let Some(x) = a.borrow_mut().as_mut() {
            push_stage(x, stage, offset_us, dur_us, blocked);
        }
    });
}

fn push_stage(x: &mut Active, stage: Stage, offset_us: f64, dur_us: f64, blocked: bool) {
    if (x.n as usize) < MAX_STAGES {
        x.stages[x.n as usize] = StageRec {
            stage,
            offset_us,
            dur_us,
            blocked,
        };
        x.n += 1;
    }
}

/// Close the current request scope. When armed, the completed trace goes
/// to this thread's ring and (if at or over the threshold) competes for a
/// slow-ring slot; the copy is returned for callers that want it. When
/// disarmed this is the relaxed load plus a `take()` of the scope —
/// no allocation, no locks.
pub fn end_request(status: u16) -> Option<CompletedTrace> {
    let active = ACTIVE.with(|a| a.borrow_mut().take())?;
    if !armed() {
        return None;
    }
    let done = CompletedTrace {
        id: active.id,
        unix_ms: SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0),
        total_us: active.start.elapsed().as_secs_f64() * 1e6,
        status,
        n_stages: active.n,
        stages: active.stages,
    };
    record_completed(&done);
    Some(done)
}

fn record_completed(t: &CompletedTrace) {
    RING.with(|cell| {
        let ring = cell.get_or_init(|| {
            // First armed record on this thread: allocate its ring once
            // and register it for snapshotting. Never runs disarmed.
            let r = Arc::new(Mutex::new(Ring {
                entries: Vec::with_capacity(RING_CAP),
                next: 0,
            }));
            RINGS
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .push(Arc::clone(&r));
            r
        });
        let mut g = ring.lock().unwrap_or_else(|p| p.into_inner());
        if g.entries.len() < RING_CAP {
            g.entries.push(*t);
        } else {
            let i = g.next % RING_CAP;
            g.entries[i] = *t;
            g.next = (g.next + 1) % RING_CAP;
        }
    });
    if t.total_us >= SLOW_THRESHOLD_US.load(Ordering::Relaxed) as f64 {
        let mut slow = SLOW.lock().unwrap_or_else(|p| p.into_inner());
        if slow.len() < SLOW_RING_CAP {
            slow.push(*t);
        } else {
            // Bounded: evict the fastest resident iff the newcomer is
            // slower, keeping the worst SLOW_RING_CAP requests.
            let (i, min_us) = slow
                .iter()
                .enumerate()
                .fold((0usize, f64::INFINITY), |acc, (i, e)| {
                    if e.total_us < acc.1 {
                        (i, e.total_us)
                    } else {
                        acc
                    }
                });
            if t.total_us > min_us {
                slow[i] = *t;
            }
        }
    }
}

/// The slow ring, worst first.
pub fn slow_snapshot() -> Vec<CompletedTrace> {
    let mut out = SLOW.lock().unwrap_or_else(|p| p.into_inner()).clone();
    out.sort_by(|a, b| b.total_us.partial_cmp(&a.total_us).unwrap_or(std::cmp::Ordering::Equal));
    out
}

/// Most recently completed traces across every thread's ring, newest
/// first, capped at `limit`.
pub fn recent_snapshot(limit: usize) -> Vec<CompletedTrace> {
    let rings: Vec<Arc<Mutex<Ring>>> = RINGS
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .iter()
        .map(Arc::clone)
        .collect();
    let mut out = Vec::new();
    for ring in rings {
        out.extend(ring.lock().unwrap_or_else(|p| p.into_inner()).entries.iter().copied());
    }
    out.sort_by(|a, b| b.unix_ms.cmp(&a.unix_ms));
    out.truncate(limit);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minted_ids_are_32_hex_and_distinct() {
        let a = TraceId::mint();
        let b = TraceId::mint();
        for id in [&a, &b] {
            assert_eq!(id.as_str().len(), 32);
            assert!(id.as_str().bytes().all(|c| c.is_ascii_hexdigit()));
        }
        assert_ne!(a, b);
    }

    #[test]
    fn parse_validates_and_truncates() {
        let id = TraceId::parse("abc-DEF_123").unwrap();
        assert_eq!(id.as_str(), "abc-DEF_123");
        // Truncation, not rejection, past the cap.
        let long = "x".repeat(100);
        assert_eq!(TraceId::parse(&long).unwrap().as_str().len(), TRACE_ID_MAX_LEN);
        // Whitespace trimmed; invalid bytes and empties rejected.
        assert_eq!(TraceId::parse("  ok  ").unwrap().as_str(), "ok");
        assert!(TraceId::parse("").is_none());
        assert!(TraceId::parse("   ").is_none());
        assert!(TraceId::parse("no spaces").is_none());
        assert!(TraceId::parse("semi;colon").is_none());
        assert!(TraceId::parse("új-id").is_none());
    }

    #[test]
    fn disarmed_scope_keeps_id_but_records_nothing() {
        assert!(!armed());
        let id = TraceId::parse("t-disarmed").unwrap();
        begin_request(id);
        assert_eq!(current_trace(), id);
        record_stage(Stage::Parse, 5.0);
        assert!(end_request(200).is_none());
        assert!(current_trace().is_none());
    }

    #[test]
    fn armed_scope_builds_span_tree_and_slow_ring() {
        let _g = arm(0);
        begin_request(TraceId::parse("t-armed").unwrap());
        record_stage(Stage::Parse, 3.0);
        record_stage_at(Stage::QueueWait, 3.0, 11.0, false);
        record_stage_at(Stage::Eval, 14.0, 20.0, true);
        record_stage(Stage::Serialize, 2.0);
        let done = end_request(200).expect("armed end returns the trace");
        assert_eq!(done.status, 200);
        let names: Vec<&str> = done.stages().iter().map(|s| s.stage.name()).collect();
        assert_eq!(names, ["parse", "queue_wait", "eval", "serialize"]);
        assert!(done.stages()[2].blocked);
        let slow = slow_snapshot();
        assert_eq!(slow.len(), 1);
        assert_eq!(slow[0].id.as_str(), "t-armed");
        let j = slow[0].to_json();
        assert_eq!(
            j.get("request_id").and_then(|v| v.as_str()),
            Some("t-armed")
        );
        let stages = j.get("stages").and_then(|s| s.as_arr()).unwrap();
        assert_eq!(stages.len(), 4);
        assert_eq!(
            stages[2].get("path").and_then(|v| v.as_str()),
            Some("block")
        );
        let recent = recent_snapshot(16);
        assert!(recent.iter().any(|t| t.id.as_str() == "t-armed"));
    }

    #[test]
    fn slow_ring_keeps_the_worst_and_stays_bounded() {
        let _g = arm(0);
        for i in 0..(SLOW_RING_CAP + 40) {
            begin_request(TraceId::mint());
            // Synthetic totals: monotonically later requests are slower.
            std::thread::sleep(std::time::Duration::from_micros(1 + i as u64 % 3));
            end_request(200);
        }
        let slow = slow_snapshot();
        assert_eq!(slow.len(), SLOW_RING_CAP);
        // Worst-first ordering.
        for w in slow.windows(2) {
            assert!(w[0].total_us >= w[1].total_us);
        }
    }

    #[test]
    fn threshold_filters_the_slow_ring() {
        let _g = arm(60_000_000); // 60 s: nothing in a test qualifies
        begin_request(TraceId::mint());
        end_request(200);
        assert!(slow_snapshot().is_empty());
        // …but the per-thread ring still records it.
        assert!(!recent_snapshot(4).is_empty());
    }
}
