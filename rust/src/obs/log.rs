//! Structured, leveled, rate-limited JSON logging to stderr.
//!
//! Replaces the ad-hoc `eprintln!` calls scattered through the serving
//! stack. Every line is one compact JSON object — machine-parseable,
//! deterministically keyed (the `Json` writer sorts keys) — stamped with
//! a wall-clock timestamp, the level, and the active request id when the
//! calling thread is inside a request scope ([`crate::obs::trace`]).
//!
//! The logger is **rate-limited** ([`MAX_LINES_PER_SEC`] lines per
//! wall-clock second, process-wide): a misbehaving client or a crash loop
//! cannot turn the telemetry channel into its own outage. Dropped lines
//! are counted and the count is attached (`dropped_lines`) to the first
//! line admitted in the next second, so the gap is visible rather than
//! silent. Logs go to **stderr** only — stdout carries the server's
//! startup lines (`listening on http://…`) that `ci/http_smoke.sh`
//! scrapes, and the two streams must not interleave.

use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::util::Json;

/// Process-wide ceiling on emitted lines per wall-clock second.
pub const MAX_LINES_PER_SEC: u64 = 200;

/// Log severities, most severe first. `--log-level` picks the threshold;
/// lines *above* the threshold (numerically greater) are skipped before
/// any formatting work.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    pub fn name(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse a `--log-level` value (case-insensitive). `None` on unknown
    /// names so the CLI can report the valid set.
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    fn from_u8(v: u8) -> Level {
        match v {
            0 => Level::Error,
            1 => Level::Warn,
            2 => Level::Info,
            _ => Level::Debug,
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

// Rate-limiter state: the wall-clock second the current window belongs
// to, how many lines it admitted, and how many it dropped.
static WINDOW_SEC: AtomicU64 = AtomicU64::new(0);
static EMITTED: AtomicU64 = AtomicU64::new(0);
static DROPPED: AtomicU64 = AtomicU64::new(0);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    Level::from_u8(LEVEL.load(Ordering::Relaxed))
}

/// True when a line at `at` would pass the threshold — callers with
/// expensive field construction can gate on this first.
#[inline]
pub fn enabled(at: Level) -> bool {
    (at as u8) <= LEVEL.load(Ordering::Relaxed)
}

/// Rate-limiter admission for a line at wall-clock second `now_s`.
/// Returns `(admitted, dropped_from_closed_window)`; the drop count is
/// nonzero only on the first admitted line after a lossy window closes.
fn admit_at(now_s: u64) -> (bool, u64) {
    let window = WINDOW_SEC.load(Ordering::Relaxed);
    let mut carried = 0;
    if window != now_s
        && WINDOW_SEC
            .compare_exchange(window, now_s, Ordering::Relaxed, Ordering::Relaxed)
            .is_ok()
    {
        // This thread rolled the window: reset the budget and claim any
        // drops from the previous window for reporting.
        EMITTED.store(0, Ordering::Relaxed);
        carried = DROPPED.swap(0, Ordering::Relaxed);
    }
    if EMITTED.fetch_add(1, Ordering::Relaxed) < MAX_LINES_PER_SEC {
        (true, carried)
    } else {
        DROPPED.fetch_add(1, Ordering::Relaxed);
        // A denied roller still carries the count forward.
        if carried > 0 {
            DROPPED.fetch_add(carried, Ordering::Relaxed);
        }
        (false, 0)
    }
}

/// Emit one structured line at `at` with message `msg` plus extra fields.
/// Skipped lines (level or rate limit) cost one atomic load / a couple of
/// atomic ops — no formatting, no allocation.
pub fn log(at: Level, msg: &str, fields: impl IntoIterator<Item = (&'static str, Json)>) {
    if !enabled(at) {
        return;
    }
    let now = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let (admitted, dropped) = admit_at(now.as_secs());
    if !admitted {
        return;
    }
    let mut map = std::collections::BTreeMap::new();
    map.insert("ts_ms".to_string(), Json::num(now.as_millis() as f64));
    map.insert("level".to_string(), Json::str(at.name()));
    map.insert("msg".to_string(), Json::str(msg));
    let rid = crate::obs::current_trace();
    if !rid.is_none() {
        map.insert("request_id".to_string(), Json::str(rid.as_str()));
    }
    if dropped > 0 {
        map.insert("dropped_lines".to_string(), Json::num(dropped as f64));
    }
    for (k, v) in fields {
        map.insert(k.to_string(), v);
    }
    eprintln!("{}", Json::Obj(map).to_string_compact());
}

pub fn error(msg: &str, fields: impl IntoIterator<Item = (&'static str, Json)>) {
    log(Level::Error, msg, fields);
}

pub fn warn(msg: &str, fields: impl IntoIterator<Item = (&'static str, Json)>) {
    log(Level::Warn, msg, fields);
}

pub fn info(msg: &str, fields: impl IntoIterator<Item = (&'static str, Json)>) {
    log(Level::Info, msg, fields);
}

pub fn debug(msg: &str, fields: impl IntoIterator<Item = (&'static str, Json)>) {
    log(Level::Debug, msg, fields);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing_and_ordering() {
        assert_eq!(Level::parse("INFO"), Some(Level::Info));
        assert_eq!(Level::parse(" warn "), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("verbose"), None);
        assert!(Level::Error < Level::Debug);
    }

    #[test]
    fn threshold_gates_levels() {
        let prev = level();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(prev);
    }

    #[test]
    fn rate_limiter_admits_caps_and_reports_drops() {
        // Fake epoch seconds far from real time so concurrent tests that
        // actually log (real clock) cannot collide with these windows.
        let s0 = 7_777_001u64;
        let mut admitted = 0;
        for _ in 0..(MAX_LINES_PER_SEC + 50) {
            if admit_at(s0).0 {
                admitted += 1;
            }
        }
        assert_eq!(admitted, MAX_LINES_PER_SEC);
        // First line of the next second reports the 50 drops.
        let (ok, dropped) = admit_at(s0 + 1);
        assert!(ok);
        assert_eq!(dropped, 50);
        // Subsequent lines report nothing.
        let (ok, dropped) = admit_at(s0 + 1);
        assert!(ok);
        assert_eq!(dropped, 0);
    }
}
