//! Mergeable fixed-layout latency histograms.
//!
//! Reservoir samples (coordinator::metrics) are *not* mergeable: two
//! uniform reservoirs with different `seen` counts cannot be concatenated
//! into a uniform sample of the union, so fleet percentiles computed that
//! way are statistically wrong. These histograms are the mergeable
//! companion: 64 half-octave (√2-ratio) log₂ buckets over nanoseconds,
//! covering ~384 ns to beyond 10 s with sub-µs underflow and a saturating
//! overflow bucket. Bucket edges are *fixed across the fleet*, so
//! histograms from any number of shards, replicas or processes sum
//! **exactly** — bucket counts, totals and duration sums are all plain
//! integer additions — and percentiles of the sum are percentiles of the
//! union (to within one bucket's resolution).
//!
//! Recording is lock-free: one relaxed `fetch_add` per bucket/count/sum,
//! safe to call from every shard worker with zero contention cost on the
//! hot path. The bucket-index function is transliterated in
//! `python/tests/test_obs_transliteration.py` with pinned cross-language
//! vectors — change one side only in lockstep with the other.

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of buckets. 64 half-octave buckets span ~2³² ns (≈4.3 s of
/// dynamic range above the 256 ns floor; the top bucket saturates).
pub const HIST_BUCKETS: usize = 64;

/// `raw = 2·msb(ns) + half` is offset by this so bucket 0 starts at
/// sub-µs values (raw 16 ⇔ 256 ns).
const RAW_OFFSET: u32 = 16;

/// Bucket index for a duration in microseconds. Half-octave log₂ layout:
/// `msb` is the highest set bit of the duration in integer nanoseconds,
/// `half` its next bit, giving two buckets per power of two.
#[inline]
pub fn bucket_index(us: f64) -> usize {
    let ns = duration_ns(us).max(1);
    let msb = 63 - ns.leading_zeros();
    let half = if msb == 0 {
        0
    } else {
        ((ns >> (msb - 1)) & 1) as u32
    };
    let raw = 2 * msb + half;
    raw.saturating_sub(RAW_OFFSET).min(HIST_BUCKETS as u32 - 1) as usize
}

/// Microseconds → integer nanoseconds, rounding half-up (`floor(x+0.5)`,
/// saturating at u64::MAX — the float-to-int cast saturates). Half-up
/// rather than `f64::round` or Python's banker's rounding because both
/// languages can express it identically: `int(us * 1000 + 0.5)`.
#[inline]
fn duration_ns(us: f64) -> u64 {
    if us <= 0.0 {
        0
    } else {
        (us * 1000.0 + 0.5) as u64
    }
}

/// Inclusive lower edge of bucket `k`, in µs (0 for the underflow bucket).
pub fn bucket_lower_us(k: usize) -> f64 {
    if k == 0 {
        return 0.0;
    }
    let raw = k.min(HIST_BUCKETS - 1) as u32 + RAW_OFFSET;
    let msb = raw / 2;
    let half = (raw % 2) as u64;
    let ns = (1u64 << msb) + half * (1u64 << (msb - 1));
    ns as f64 / 1000.0
}

/// Exclusive upper edge of bucket `k`, in µs. The top bucket is open; its
/// nominal edge (2× its lower edge) only shapes within-bucket
/// interpolation.
pub fn bucket_upper_us(k: usize) -> f64 {
    if k + 1 >= HIST_BUCKETS {
        bucket_lower_us(HIST_BUCKETS - 1) * 2.0
    } else {
        bucket_lower_us(k + 1)
    }
}

/// Lock-free recording side: one instance per (shard, stage).
pub struct AtomicLogHist {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum_ns: AtomicU64,
}

impl Default for AtomicLogHist {
    fn default() -> Self {
        Self::new()
    }
}

impl AtomicLogHist {
    pub const fn new() -> AtomicLogHist {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        AtomicLogHist {
            buckets: [ZERO; HIST_BUCKETS],
            count: AtomicU64::new(0),
            sum_ns: AtomicU64::new(0),
        }
    }

    /// Record one duration (µs). Three relaxed `fetch_add`s, no locks.
    #[inline]
    pub fn record(&self, us: f64) {
        self.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(duration_ns(us), Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self
                .buckets
                .iter()
                .map(|b| b.load(Ordering::Relaxed))
                .collect(),
            count: self.count.load(Ordering::Relaxed),
            sum_ns: self.sum_ns.load(Ordering::Relaxed),
        }
    }
}

/// A point-in-time copy of a histogram: the mergeable, serializable form.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    /// Per-bucket counts, [`HIST_BUCKETS`] long.
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum_ns: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        HistSnapshot {
            buckets: vec![0; HIST_BUCKETS],
            count: 0,
            sum_ns: 0,
        }
    }
}

impl HistSnapshot {
    /// Exact merge: elementwise bucket sums plus count/sum totals.
    pub fn merge(&mut self, other: &HistSnapshot) {
        if self.buckets.len() < other.buckets.len() {
            self.buckets.resize(other.buckets.len(), 0);
        }
        for (a, &b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
    }

    /// Percentile estimate in µs (q ∈ [0,1]): walk the cumulative counts
    /// to the target rank, then interpolate linearly within the bucket.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = q.clamp(0.0, 1.0) * self.count as f64;
        let mut cum = 0u64;
        for (k, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            let prev = cum as f64;
            cum += c;
            if cum as f64 >= rank {
                let lo = bucket_lower_us(k);
                let hi = bucket_upper_us(k);
                let frac = ((rank - prev) / c as f64).clamp(0.0, 1.0);
                return lo + frac * (hi - lo);
            }
        }
        bucket_upper_us(HIST_BUCKETS - 1)
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64 / 1000.0
        }
    }

    pub fn sum_us(&self) -> f64 {
        self.sum_ns as f64 / 1000.0
    }

    /// Wire form: `{"buckets": [u64; 64], "count": n, "sum_us": x}`.
    pub fn to_json(&self) -> crate::util::Json {
        use crate::util::Json;
        Json::obj([
            (
                "buckets",
                Json::arr(self.buckets.iter().map(|&c| Json::num(c as f64))),
            ),
            ("count", Json::num(self.count as f64)),
            ("sum_us", Json::num(self.sum_us())),
        ])
    }

    /// Parse the wire form; `None` on shape mismatch (an older replica
    /// without histograms simply contributes nothing to a merge).
    pub fn from_json(j: &crate::util::Json) -> Option<HistSnapshot> {
        let arr = j.get("buckets")?.as_arr()?;
        let mut buckets: Vec<u64> = Vec::with_capacity(arr.len());
        for v in arr {
            buckets.push(v.as_f64()? as u64);
        }
        if buckets.len() > HIST_BUCKETS {
            return None;
        }
        buckets.resize(HIST_BUCKETS, 0);
        let count = j.get("count")?.as_f64()? as u64;
        let sum_us = j.get("sum_us")?.as_f64()?;
        Some(HistSnapshot {
            buckets,
            count,
            sum_ns: (sum_us * 1000.0) as u64,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Cross-language pinned vectors — mirrored in
    /// python/tests/test_obs_transliteration.py.
    #[test]
    fn bucket_index_pinned_vectors() {
        for (us, idx) in [
            (0.0, 0),
            (0.1, 0),      // 100 ns: sub-µs underflow
            (0.383, 0),    // 383 ns: last underflow value
            (0.384, 1),    // 384 ns: first half-octave above 256·1.5
            (1.0, 3),      // 1 µs = 1000 ns: msb 9, half 1 → raw 19
            (25.4, 13),    // the paper's per-classification latency
            (1_000.0, 23), // 1 ms
            (1_000_000.0, 43),     // 1 s
            (10_000_000.0, 50),    // 10 s
            (1e12, 63),            // absurd → overflow bucket
        ] {
            assert_eq!(bucket_index(us), idx, "us={us}");
        }
    }

    #[test]
    fn edges_are_consistent_with_indexing() {
        for k in 1..HIST_BUCKETS {
            let lo = bucket_lower_us(k);
            assert_eq!(bucket_index(lo), k, "lower edge of {k} must land in {k}");
            // Just below the edge lands in the previous bucket.
            assert_eq!(bucket_index(lo - 0.001), k - 1, "below edge of {k}");
            assert!(bucket_upper_us(k - 1) == lo);
        }
    }

    #[test]
    fn merge_is_exact() {
        let a = AtomicLogHist::new();
        let b = AtomicLogHist::new();
        let all = AtomicLogHist::new();
        for i in 0..2000 {
            let us = 0.5 * 1.01f64.powi(i % 1500);
            if i % 3 == 0 {
                a.record(us);
            } else {
                b.record(us);
            }
            all.record(us);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        assert_eq!(merged, all.snapshot(), "merge must equal recording the union");
    }

    #[test]
    fn percentiles_track_the_distribution() {
        let h = AtomicLogHist::new();
        for i in 1..=10_000 {
            h.record(i as f64); // uniform 1 µs..10 ms
        }
        let s = h.snapshot();
        assert_eq!(s.count, 10_000);
        let p50 = s.percentile(0.5);
        let p99 = s.percentile(0.99);
        // Half-octave buckets bound the relative error by ~√2.
        assert!((3_300.0..=7_200.0).contains(&p50), "p50 {p50}");
        assert!((6_800.0..=14_200.0).contains(&p99), "p99 {p99}");
        assert!(p50 < p99);
        assert!((s.mean_us() - 5_000.0).abs() < 2_000.0, "{}", s.mean_us());
    }

    #[test]
    fn json_round_trip() {
        let h = AtomicLogHist::new();
        for us in [0.2, 13.0, 420.0, 1e6] {
            h.record(us);
        }
        let snap = h.snapshot();
        let back = HistSnapshot::from_json(&snap.to_json()).unwrap();
        assert_eq!(back.buckets, snap.buckets);
        assert_eq!(back.count, snap.count);
        // sum goes through f64 µs on the wire: equal to within rounding.
        assert!((back.sum_ns as i64 - snap.sum_ns as i64).abs() <= 1);
        assert!(HistSnapshot::from_json(&crate::util::Json::Null).is_none());
    }
}
