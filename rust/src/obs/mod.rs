//! Observability: request-scoped tracing, mergeable latency histograms,
//! structured logging, and Prometheus exposition — std-only, wired
//! through every tier (DESIGN.md §14).
//!
//! - [`trace`]: 128-bit request ids (minted or adopted from
//!   `X-Request-Id`), per-request span trees with stage timings, bounded
//!   per-thread rings plus a worst-request ring behind `/v1/debug/slow`.
//!   Disarmed cost: one relaxed atomic load, zero allocations.
//! - [`hist`]: fixed-layout half-octave log₂ histograms that sum
//!   **exactly** across shards and replicas — the statistically sound
//!   source for fleet percentiles (reservoirs are exemplar-only).
//! - [`log`]: leveled, rate-limited JSON lines on stderr, stamped with
//!   the active request id.
//! - [`promtext`]: the `/v1/metrics?format=prometheus` renderer, shared
//!   by replica and router tiers.

pub mod hist;
pub mod log;
pub mod promtext;
pub mod trace;

pub use hist::{AtomicLogHist, HistSnapshot, HIST_BUCKETS};
pub use log::Level;
pub use trace::{
    arm, arm_process, armed, begin_request, current_trace, elapsed_us, end_request, record_stage,
    record_stage_at, recent_snapshot, slow_snapshot, CompletedTrace, Stage, StageTiming,
    TraceGuard, TraceId, SLOW_RING_CAP,
};
