//! Hand-rolled Prometheus text-format exposition.
//!
//! Renders a metrics snapshot (the same JSON served by `/v1/metrics`)
//! into the Prometheus text format (version 0.0.4): `# HELP`/`# TYPE`
//! headers, `_total`-suffixed counters, gauges, and the three stage
//! histograms with cumulative `le` buckets in **seconds** (Prometheus
//! base unit) plus `_sum`/`_count`. Both tiers share this renderer — the
//! replica passes its own snapshot, the router passes the merged
//! aggregate — which is exactly why fleet latency comes out
//! histogram-derived: the router's aggregate carries summed buckets, not
//! concatenated reservoir samples.
//!
//! The renderer is tolerant: fields absent from the snapshot are simply
//! not exposed (an older replica without histograms still renders its
//! counters). Output conformance is linted by `ci/check_promtext.py`.

use std::fmt::Write as _;

use super::hist::{bucket_upper_us, HistSnapshot, HIST_BUCKETS};
use crate::util::Json;

/// Plain counters: snapshot field → (metric name, help).
const COUNTERS: &[(&str, &str, &str)] = &[
    ("requests", "convcotm_requests_total", "Classification requests served."),
    ("errors", "convcotm_errors_total", "Requests that failed."),
    ("batches", "convcotm_batches_total", "Evaluation batches executed."),
    (
        "latency_samples_seen",
        "convcotm_latency_samples_seen_total",
        "Latency samples offered to the exemplar reservoir.",
    ),
    (
        "shard_panics",
        "convcotm_shard_panics_total",
        "Shard worker panics caught by the supervisor.",
    ),
    ("respawns", "convcotm_respawns_total", "Shard workers respawned."),
];

/// Plain gauges: snapshot field → (metric name, help).
const GAUGES: &[(&str, &str, &str)] = &[
    (
        "throughput_rps",
        "convcotm_throughput_rps",
        "Requests per second since process start.",
    ),
    (
        "latency_p50_us",
        "convcotm_latency_p50_us",
        "Histogram-derived request latency p50 (microseconds).",
    ),
    (
        "latency_p95_us",
        "convcotm_latency_p95_us",
        "Histogram-derived request latency p95 (microseconds).",
    ),
    (
        "latency_p99_us",
        "convcotm_latency_p99_us",
        "Histogram-derived request latency p99 (microseconds).",
    ),
];

/// Stage histograms: snapshot field → (metric name, help).
const HISTOGRAMS: &[(&str, &str, &str)] = &[
    (
        "latency_hist",
        "convcotm_request_latency_seconds",
        "End-to-end request latency.",
    ),
    (
        "queue_wait_hist",
        "convcotm_queue_wait_seconds",
        "Admission to shard-worker pickup.",
    ),
    (
        "eval_hist",
        "convcotm_eval_seconds",
        "Clause evaluation (scalar and block paths).",
    ),
];

/// Render a metrics snapshot as Prometheus text.
pub fn render(snapshot: &Json) -> String {
    let mut out = String::new();
    for &(field, name, help) in COUNTERS {
        if let Some(v) = snapshot.get(field).and_then(Json::as_f64) {
            header(&mut out, name, "counter", help);
            sample(&mut out, name, &[], v);
        }
    }
    for &(field, name, help) in GAUGES {
        if let Some(v) = snapshot.get(field).and_then(Json::as_f64) {
            header(&mut out, name, "gauge", help);
            sample(&mut out, name, &[], v);
        }
    }
    if let Some(shards) = snapshot.get("shard_requests").and_then(Json::as_arr) {
        if !shards.is_empty() {
            let name = "convcotm_shard_requests_total";
            header(&mut out, name, "counter", "Requests served per shard.");
            for (i, v) in shards.iter().enumerate() {
                if let Some(v) = v.as_f64() {
                    sample(&mut out, name, &[("shard", &i.to_string())], v);
                }
            }
        }
    }
    if let Some(Json::Obj(models)) = snapshot.get("per_model") {
        if !models.is_empty() {
            for (field, name, help) in [
                ("requests", "convcotm_model_requests_total", "Requests per model."),
                ("errors", "convcotm_model_errors_total", "Errors per model."),
            ] {
                header(&mut out, name, "counter", help);
                for (model, stats) in models {
                    if let Some(v) = stats.get(field).and_then(Json::as_f64) {
                        sample(&mut out, name, &[("model", model)], v);
                    }
                }
            }
        }
    }
    for &(field, name, help) in HISTOGRAMS {
        if let Some(h) = snapshot.get(field).and_then(HistSnapshot::from_json) {
            histogram(&mut out, name, help, &h);
        }
    }
    if let Some(Json::Obj(http)) = snapshot.get("http") {
        for (k, v) in http {
            if let Some(v) = v.as_f64() {
                let name = format!("convcotm_http_{k}");
                header(&mut out, &name, "gauge", "HTTP front-door statistic.");
                sample(&mut out, &name, &[], v);
            }
        }
    }
    out
}

fn header(out: &mut String, name: &str, kind: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

fn sample(out: &mut String, name: &str, labels: &[(&str, &str)], value: f64) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "{k}=\"{}\"", escape_label(v));
        }
        out.push('}');
    }
    out.push(' ');
    push_value(out, value);
    out.push('\n');
}

/// One histogram: cumulative `le` buckets (seconds) + `_sum`/`_count`.
fn histogram(out: &mut String, name: &str, help: &str, h: &HistSnapshot) {
    header(out, name, "histogram", help);
    let mut cum = 0u64;
    for (k, &c) in h.buckets.iter().enumerate().take(HIST_BUCKETS - 1) {
        cum += c;
        // Skip interior empty-prefix noise? No: Prometheus histograms are
        // fixed-layout; every bucket must appear so scrapes from
        // different processes align. 64 lines per metric is cheap.
        let le = bucket_upper_us(k) / 1e6;
        out.push_str(name);
        let _ = write!(out, "_bucket{{le=\"{le}\"}} {cum}");
        out.push('\n');
    }
    let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count);
    out.push_str(name);
    out.push_str("_sum ");
    push_value(out, h.sum_us() / 1e6);
    out.push('\n');
    let _ = writeln!(out, "{name}_count {}", h.count);
}

fn push_value(out: &mut String, v: f64) {
    if !v.is_finite() {
        out.push_str(if v.is_nan() {
            "NaN"
        } else if v > 0.0 {
            "+Inf"
        } else {
            "-Inf"
        });
    } else if v == v.trunc() && v.abs() < 1e15 {
        let _ = write!(out, "{}", v as i64);
    } else {
        let _ = write!(out, "{v}");
    }
}

fn escape_label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::AtomicLogHist;

    fn snapshot_fixture() -> Json {
        let h = AtomicLogHist::new();
        for us in [12.0, 25.4, 90.0, 400.0, 2_000.0] {
            h.record(us);
        }
        let mut per_model = std::collections::BTreeMap::new();
        per_model.insert(
            "mnist\"v1".to_string(),
            Json::obj([("requests", Json::num(4)), ("errors", Json::num(1))]),
        );
        Json::obj([
            ("requests", Json::num(5)),
            ("errors", Json::num(1)),
            ("batches", Json::num(2)),
            ("latency_samples_seen", Json::num(5)),
            ("shard_panics", Json::num(0)),
            ("respawns", Json::num(0)),
            ("throughput_rps", Json::num(123.5)),
            ("latency_p50_us", Json::num(95.0)),
            ("latency_p95_us", Json::num(1800.0)),
            ("latency_p99_us", Json::num(1990.0)),
            (
                "shard_requests",
                Json::arr([Json::num(3), Json::num(2)]),
            ),
            ("per_model", Json::Obj(per_model)),
            ("latency_hist", h.snapshot().to_json()),
        ])
    }

    #[test]
    fn renders_counters_gauges_and_labels() {
        let text = render(&snapshot_fixture());
        assert!(text.contains("# TYPE convcotm_requests_total counter"));
        assert!(text.contains("convcotm_requests_total 5\n"));
        assert!(text.contains("# TYPE convcotm_throughput_rps gauge"));
        assert!(text.contains("convcotm_throughput_rps 123.5\n"));
        assert!(text.contains("convcotm_shard_requests_total{shard=\"0\"} 3\n"));
        assert!(text.contains("convcotm_shard_requests_total{shard=\"1\"} 2\n"));
        // Label values are escaped, not emitted raw.
        assert!(text.contains("convcotm_model_requests_total{model=\"mnist\\\"v1\"} 4\n"));
        // Every HELP precedes its TYPE which precedes its samples.
        let help_at = text.find("# HELP convcotm_requests_total").unwrap();
        let type_at = text.find("# TYPE convcotm_requests_total").unwrap();
        let sample_at = text.find("\nconvcotm_requests_total 5").unwrap();
        assert!(help_at < type_at && type_at < sample_at);
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_capped_by_inf() {
        let text = render(&snapshot_fixture());
        assert!(text.contains("# TYPE convcotm_request_latency_seconds histogram"));
        let mut prev = 0u64;
        let mut bucket_lines = 0;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("convcotm_request_latency_seconds_bucket{le=\"")
            {
                let (le, count) = rest.split_once("\"} ").unwrap();
                let count: u64 = count.parse().unwrap();
                assert!(count >= prev, "cumulative counts must not decrease");
                prev = count;
                if le != "+Inf" {
                    let _: f64 = le.parse().expect("le parses as a float");
                }
                bucket_lines += 1;
            }
        }
        assert_eq!(bucket_lines, HIST_BUCKETS, "63 finite edges + +Inf");
        assert!(text.contains("convcotm_request_latency_seconds_count 5\n"));
        assert!(text.contains("convcotm_request_latency_seconds_bucket{le=\"+Inf\"} 5\n"));
    }

    #[test]
    fn absent_fields_are_skipped_not_zeroed() {
        let text = render(&Json::obj([("requests", Json::num(1))]));
        assert!(text.contains("convcotm_requests_total 1\n"));
        assert!(!text.contains("convcotm_errors_total"));
        assert!(!text.contains("_bucket"));
    }
}
