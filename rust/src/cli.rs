//! Minimal CLI argument parser (clap is not vendored): subcommand + flags
//! of the forms `--key value`, `--key=value` and boolean `--flag`. Flags
//! are repeatable: every occurrence is kept in order ([`Args::get_all`]),
//! which is how `serve --model a=x.cctm --model b=y.cctm` loads several
//! models; single-value accessors ([`Args::get`]) take the last
//! occurrence, preserving the usual "rightmost flag wins" override.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, flags and positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    flags: BTreeMap<String, Vec<String>>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args, String> {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if stripped.is_empty() {
                    // `--` terminates flag parsing.
                    out.positionals.extend(iter);
                    break;
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.push_flag(k, v);
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.push_flag(stripped, &v);
                } else {
                    out.push_flag(stripped, "true");
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                out.positionals.push(arg);
            }
        }
        Ok(out)
    }

    fn push_flag(&mut self, key: &str, value: &str) {
        self.flags
            .entry(key.to_string())
            .or_default()
            .push(value.to_string());
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    /// Last occurrence of `--key` (rightmost wins), if any.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .get(key)
            .and_then(|vs| vs.last())
            .map(|s| s.as_str())
    }

    /// Every occurrence of `--key`, in command-line order.
    pub fn get_all(&self, key: &str) -> &[String] {
        self.flags.get(key).map(|vs| vs.as_slice()).unwrap_or(&[])
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["train", "--dataset", "mnist", "--epochs=5", "--quick"]);
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get("dataset"), Some("mnist"));
        assert_eq!(a.get_usize("epochs", 1).unwrap(), 5);
        assert!(a.get_bool("quick"));
        assert!(!a.get_bool("missing"));
    }

    #[test]
    fn repeated_flags_accumulate_and_last_wins_for_get() {
        let a = parse(&[
            "serve",
            "--model",
            "mnist=models/a.cctm",
            "--model=cifar=models/b.cctm",
            "--shards",
            "4",
        ]);
        assert_eq!(
            a.get_all("model"),
            &["mnist=models/a.cctm", "cifar=models/b.cctm"]
        );
        // Note: `--model=cifar=...` splits on the first '=' only.
        assert_eq!(a.get("model"), Some("cifar=models/b.cctm"));
        assert_eq!(a.get_usize("shards", 1).unwrap(), 4);
        assert!(a.get_all("absent").is_empty());
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["bench"]);
        assert_eq!(a.get_or("dataset", "mnist"), "mnist");
        assert_eq!(a.get_usize("epochs", 12).unwrap(), 12);
        assert_eq!(a.get_f64("freq", 27.8e6).unwrap(), 27.8e6);
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["x", "--epochs", "five"]);
        assert!(a.get_usize("epochs", 1).is_err());
    }

    #[test]
    fn double_dash_stops_flag_parsing() {
        let a = parse(&["run", "--flag", "--", "--not-a-flag"]);
        assert!(a.get_bool("flag"));
        assert_eq!(a.positionals, vec!["--not-a-flag"]);
    }

    #[test]
    fn positionals_collected() {
        let a = parse(&["classify", "img1.bin", "img2.bin"]);
        assert_eq!(a.positionals.len(), 2);
    }
}
