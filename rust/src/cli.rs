//! Minimal CLI argument parser (clap is not vendored): subcommand + flags
//! of the forms `--key value`, `--key=value` and boolean `--flag`.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, flags and positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    flags: BTreeMap<String, String>,
    pub positionals: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args, String> {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if stripped.is_empty() {
                    // `--` terminates flag parsing.
                    out.positionals.extend(iter);
                    break;
                }
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else if out.command.is_none() {
                out.command = Some(arg);
            } else {
                out.positionals.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, String> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["train", "--dataset", "mnist", "--epochs=5", "--quick"]);
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get("dataset"), Some("mnist"));
        assert_eq!(a.get_usize("epochs", 1).unwrap(), 5);
        assert!(a.get_bool("quick"));
        assert!(!a.get_bool("missing"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["bench"]);
        assert_eq!(a.get_or("dataset", "mnist"), "mnist");
        assert_eq!(a.get_usize("epochs", 12).unwrap(), 12);
        assert_eq!(a.get_f64("freq", 27.8e6).unwrap(), 27.8e6);
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["x", "--epochs", "five"]);
        assert!(a.get_usize("epochs", 1).is_err());
    }

    #[test]
    fn double_dash_stops_flag_parsing() {
        let a = parse(&["run", "--flag", "--", "--not-a-flag"]);
        assert!(a.get_bool("flag"));
        assert_eq!(a.positionals, vec!["--not-a-flag"]);
    }

    #[test]
    fn positionals_collected() {
        let a = parse(&["classify", "img1.bin", "img2.bin"]);
        assert_eq!(a.positionals.len(), 2);
    }
}
