//! PJRT runtime: load the AOT-compiled HLO-text artifacts and execute them
//! from the Rust request path (the session architecture's L3↔L2 bridge).
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! One compiled executable per artifact variant (batch 1, batch 16);
//! executables are cached in the [`Runtime`].

use crate::data::boolean::BoolImage;
use crate::tm::Model;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Flattened f32 model inputs for the compiled graph.
pub struct ModelInputs {
    /// (128×272) row-major 0/1.
    pub include: Vec<f32>,
    /// (10×128) row-major.
    pub weights: Vec<f32>,
}

impl ModelInputs {
    pub fn from_model(model: &Model) -> ModelInputs {
        let p = &model.params;
        let mut include = Vec::with_capacity(p.clauses * p.literals);
        for j in 0..p.clauses {
            for k in 0..p.literals {
                include.push(if model.include(j).get(k) { 1.0 } else { 0.0 });
            }
        }
        let mut weights = Vec::with_capacity(p.classes * p.clauses);
        for i in 0..p.classes {
            for j in 0..p.clauses {
                weights.push(model.weight(i, j) as f32);
            }
        }
        ModelInputs { include, weights }
    }
}

/// Flatten a booleanized image to the graph's (784,) f32 layout.
pub fn image_to_f32(img: &BoolImage) -> Vec<f32> {
    let mut v = Vec::with_capacity(784);
    for y in 0..28 {
        for x in 0..28 {
            v.push(if img.get(x, y) { 1.0 } else { 0.0 });
        }
    }
    v
}

/// Result of one graph execution for one image.
#[derive(Clone, Debug, PartialEq)]
pub struct GraphOutput {
    pub class_sums: Vec<f32>,
    pub clauses: Vec<f32>,
    pub prediction: u8,
}

/// A compiled executable plus its batch size.
pub struct CompiledGraph {
    exe: xla::PjRtLoadedExecutable,
    pub batch: usize,
    pub clauses: usize,
    pub classes: usize,
    pub literals: usize,
}

impl CompiledGraph {
    /// Execute on up to `batch` images (padded internally with zeros).
    /// Returns one output per input image.
    pub fn run(&self, images: &[&BoolImage], model: &ModelInputs) -> Result<Vec<GraphOutput>> {
        if images.is_empty() {
            return Ok(Vec::new());
        }
        if images.len() > self.batch {
            return Err(anyhow!(
                "batch overflow: {} images into a batch-{} graph",
                images.len(),
                self.batch
            ));
        }
        // Pack (+pad) the image tensor.
        let mut img_data = vec![0f32; self.batch * 784];
        for (b, img) in images.iter().enumerate() {
            img_data[b * 784..(b + 1) * 784].copy_from_slice(&image_to_f32(img));
        }
        let img_lit = if self.batch == 1 {
            xla::Literal::vec1(&img_data)
        } else {
            xla::Literal::vec1(&img_data).reshape(&[self.batch as i64, 784])?
        };
        let include_lit = xla::Literal::vec1(&model.include)
            .reshape(&[self.clauses as i64, self.literals as i64])?;
        let weights_lit = xla::Literal::vec1(&model.weights)
            .reshape(&[self.classes as i64, self.clauses as i64])?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[img_lit, include_lit, weights_lit])?[0][0]
            .to_literal_sync()?;
        // return_tuple=True at lowering → 3-tuple (sums, clauses, pred).
        let (sums_l, clauses_l, pred_l) = result.to_tuple3()?;
        let sums = sums_l.to_vec::<f32>()?;
        let clauses = clauses_l.to_vec::<f32>()?;
        let preds = pred_l.to_vec::<f32>()?;
        let per = |b: usize| GraphOutput {
            class_sums: sums[b * self.classes..(b + 1) * self.classes].to_vec(),
            clauses: clauses[b * self.clauses..(b + 1) * self.clauses].to_vec(),
            prediction: preds[b] as u8,
        };
        Ok((0..images.len()).map(per).collect())
    }
}

/// The PJRT client + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
    cache: HashMap<String, CompiledGraph>,
}

impl Runtime {
    /// Create a CPU PJRT client rooted at an artifact directory.
    pub fn new(artifact_dir: impl Into<PathBuf>) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client,
            artifact_dir: artifact_dir.into(),
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached). `name` is e.g. "convcotm_b1".
    pub fn load(&mut self, name: &str, batch: usize) -> Result<&CompiledGraph> {
        if !self.cache.contains_key(name) {
            let path = self.artifact_dir.join(format!("{name}.hlo.txt"));
            let graph = self.compile_file(&path, batch)?;
            self.cache.insert(name.to_string(), graph);
        }
        Ok(&self.cache[name])
    }

    /// Compile an HLO-text file directly.
    pub fn compile_file(&self, path: &Path, batch: usize) -> Result<CompiledGraph> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(CompiledGraph {
            exe,
            batch,
            clauses: 128,
            classes: 10,
            literals: 272,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tm::Params;
    use crate::util::Xoshiro256ss;

    fn artifact_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifact_dir().join("convcotm_b1.hlo.txt").exists()
    }

    fn random_model(seed: u64) -> Model {
        let params = Params::asic();
        let mut rng = Xoshiro256ss::new(seed);
        let mut m = Model::blank(params.clone());
        for j in 0..params.clauses {
            for _ in 0..1 + rng.usize_below(6) {
                m.set_include(j, rng.usize_below(params.literals), true);
            }
            for i in 0..params.classes {
                m.set_weight(i, j, (rng.below(255) as i32 - 127) as i8);
            }
        }
        m
    }

    fn random_image(rng: &mut Xoshiro256ss) -> BoolImage {
        BoolImage::from_bools(&(0..784).map(|_| rng.chance(0.3)).collect::<Vec<_>>())
    }

    #[test]
    fn model_inputs_layout() {
        let model = random_model(1);
        let mi = ModelInputs::from_model(&model);
        assert_eq!(mi.include.len(), 128 * 272);
        assert_eq!(mi.weights.len(), 10 * 128);
        // Spot-check: include[j,k] row-major.
        let j = 3;
        let k = model.included_literals(j)[0];
        assert_eq!(mi.include[j * 272 + k], 1.0);
        assert_eq!(mi.weights[2 * 128 + 5], model.weight(2, 5) as f32);
    }

    #[test]
    fn image_layout_row_major() {
        let mut img = BoolImage::blank();
        img.set(2, 0, true);
        img.set(0, 1, true);
        let v = image_to_f32(&img);
        assert_eq!(v[2], 1.0);
        assert_eq!(v[28], 1.0);
        assert_eq!(v.iter().sum::<f32>(), 2.0);
    }

    /// The cross-stack golden test: the PJRT-executed JAX artifact must
    /// match the native engine bit-for-bit (the paper's "ASIC matches SW"
    /// property, across our L1/L2/L3 stack).
    #[test]
    fn pjrt_artifact_matches_native_engine() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::new(artifact_dir()).unwrap();
        let model = random_model(2);
        let mi = ModelInputs::from_model(&model);
        let engine = crate::tm::Engine::new();
        let graph = rt.load("convcotm_b1", 1).unwrap();
        let mut rng = Xoshiro256ss::new(77);
        for _ in 0..4 {
            let img = random_image(&mut rng);
            let out = &graph.run(&[&img], &mi).unwrap()[0];
            let sw = engine.classify(&model, &img);
            assert_eq!(out.prediction, sw.prediction);
            let sums_i32: Vec<i32> = out.class_sums.iter().map(|&x| x as i32).collect();
            assert_eq!(sums_i32, sw.class_sums);
            for j in 0..128 {
                assert_eq!(out.clauses[j] > 0.5, sw.clauses.get(j), "clause {j}");
            }
        }
    }

    #[test]
    fn pjrt_batch16_matches_native_engine() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::new(artifact_dir()).unwrap();
        let model = random_model(3);
        let mi = ModelInputs::from_model(&model);
        let engine = crate::tm::Engine::new();
        let graph = rt.load("convcotm_b16", 16).unwrap();
        let mut rng = Xoshiro256ss::new(99);
        let imgs: Vec<BoolImage> = (0..11).map(|_| random_image(&mut rng)).collect();
        let refs: Vec<&BoolImage> = imgs.iter().collect();
        let outs = graph.run(&refs, &mi).unwrap();
        assert_eq!(outs.len(), 11, "padded batch returns only real outputs");
        for (img, out) in imgs.iter().zip(&outs) {
            let sw = engine.classify(&model, img);
            assert_eq!(out.prediction, sw.prediction);
        }
    }

    #[test]
    fn batch_overflow_rejected() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let mut rt = Runtime::new(artifact_dir()).unwrap();
        let model = random_model(4);
        let mi = ModelInputs::from_model(&model);
        let graph = rt.load("convcotm_b1", 1).unwrap();
        let mut rng = Xoshiro256ss::new(5);
        let a = random_image(&mut rng);
        let b = random_image(&mut rng);
        assert!(graph.run(&[&a, &b], &mi).is_err());
    }
}
