//! §VI-C / Table III: the envisaged scaled-up TM-Composites accelerator
//! for CIFAR-10 — four TM Specialists time-multiplexed on one configurable
//! TM module, models paged from on-chip RAM.
//!
//! This module reproduces the paper's estimation procedure as an explicit,
//! testable calculation rather than prose arithmetic.

use super::scaling::{area_scale, NODE_28NM, NODE_65NM};

/// One TM Specialist configuration (Table III).
#[derive(Clone, Debug)]
pub struct Specialist {
    pub name: &'static str,
    /// Average literals per patch.
    pub literals_per_patch: usize,
    /// Included literals per clause (literal budget, [42]).
    pub literals_per_clause: usize,
    /// Clauses in the shared pool.
    pub clauses: usize,
    /// Weight bits per clause per class.
    pub weight_bits: usize,
    pub classes: usize,
}

impl Specialist {
    /// Literal address width (⌈log2 literals⌉) — Table III uses 10 bits
    /// for 1000 literals.
    pub fn addr_bits(&self) -> usize {
        usize::BITS as usize - (self.literals_per_patch - 1).leading_zeros() as usize
    }

    /// TA-action model bytes: clauses × literals/clause × addr bits.
    pub fn ta_model_bytes(&self) -> usize {
        self.clauses * self.literals_per_clause * self.addr_bits() / 8
    }

    /// Weight model bytes: classes × clauses × weight bits.
    pub fn weight_model_bytes(&self) -> usize {
        self.classes * self.clauses * self.weight_bits / 8
    }

    pub fn model_bytes(&self) -> usize {
        self.ta_model_bytes() + self.weight_model_bytes()
    }
}

/// The paper's four specialists (Table III: color thermometers, HoG,
/// adaptive thresholding).
pub fn paper_specialists() -> Vec<Specialist> {
    let base = Specialist {
        name: "",
        literals_per_patch: 1000,
        literals_per_clause: 16,
        clauses: 1000,
        weight_bits: 10,
        classes: 10,
    };
    vec![
        Specialist { name: "4x4 color thermometer", ..base.clone() },
        Specialist { name: "3x3 color thermometer", ..base.clone() },
        Specialist { name: "32x32 histogram of gradients", ..base.clone() },
        Specialist { name: "10x10 adaptive thresholding", ..base },
    ]
}

/// Timing/energy assumptions of §VI-C.
#[derive(Clone, Debug)]
pub struct ScaleUpAssumptions {
    /// Processing cycles per sample per specialist (incl. booleanization).
    pub process_cycles: usize,
    /// Model-RAM transfer width, bytes per cycle.
    pub model_xfer_bytes_per_cycle: usize,
    /// System clock.
    pub clock_hz: f64,
    /// Reference: the measured 65 nm core power at 27.8 MHz / 0.82 V.
    pub ref_power_w: f64,
    /// Reference model size (this ASIC: 5.6 kB) for the area/power ratio R.
    pub ref_model_bytes: usize,
    /// Additional area for booleanization logic, adders, model RAM (mm²).
    pub extra_area_mm2: f64,
    /// Reference core area (65 nm ASIC).
    pub ref_area_mm2: f64,
}

impl Default for ScaleUpAssumptions {
    fn default() -> Self {
        ScaleUpAssumptions {
            process_cycles: 1000,
            model_xfer_bytes_per_cycle: 32,
            clock_hz: 27.8e6,
            ref_power_w: 0.52e-3,
            ref_model_bytes: 5_632,
            extra_area_mm2: 2.0,
            ref_area_mm2: 2.7,
        }
    }
}

/// The Table III estimate outputs.
#[derive(Clone, Debug)]
pub struct ScaleUpEstimate {
    /// Model size of one specialist (bytes).
    pub specialist_model_bytes: usize,
    /// Complete model (all specialists).
    pub total_model_bytes: usize,
    /// Cycles per classification (all specialists, incl. model paging).
    pub cycles_per_classification: usize,
    pub rate_fps: f64,
    pub latency_s: f64,
    /// Scale ratio R = specialist model / reference model.
    pub r_ratio: f64,
    pub area_65nm_mm2: f64,
    pub area_28nm_mm2: f64,
    pub power_65nm_w: f64,
    pub power_28nm_w: f64,
    pub epc_65nm_j: f64,
    pub epc_28nm_j: f64,
}

/// Reproduce the §VI-C estimation procedure.
pub fn estimate(specialists: &[Specialist], a: &ScaleUpAssumptions) -> ScaleUpEstimate {
    let specialist_model_bytes = specialists[0].model_bytes();
    let total_model_bytes: usize = specialists.iter().map(|s| s.model_bytes()).sum();
    // Model paging: bytes / width, rounded up.
    let xfer_cycles = specialist_model_bytes.div_ceil(a.model_xfer_bytes_per_cycle);
    let per_specialist = a.process_cycles + xfer_cycles;
    let cycles = per_specialist * specialists.len();
    let rate = a.clock_hz / cycles as f64;
    // R: model-size ratio drives both area and power (§VI-C: "a reasonable
    // assumption because the model storage ... and the clause logic
    // dominate the chip area").
    let r = specialist_model_bytes as f64 / a.ref_model_bytes as f64;
    let area_65 = a.ref_area_mm2 * r + a.extra_area_mm2;
    let area_28 = area_65 * area_scale(NODE_65NM, NODE_28NM);
    let power_65 = a.ref_power_w * r;
    // §VI-C: 0.7 V 28 nm ⇒ ≈50% of the 65 nm power.
    let power_28 = power_65 * 0.5;
    ScaleUpEstimate {
        specialist_model_bytes,
        total_model_bytes,
        cycles_per_classification: cycles,
        rate_fps: rate,
        latency_s: cycles as f64 / a.clock_hz,
        r_ratio: r,
        area_65nm_mm2: area_65,
        area_28nm_mm2: area_28,
        power_65nm_w: power_65,
        power_28nm_w: power_28,
        epc_65nm_j: power_65 / rate,
        epc_28nm_j: power_28 / rate,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specialist_model_sizes_match_table3() {
        let s = &paper_specialists()[0];
        assert_eq!(s.addr_bits(), 10, "1000 literals → 10-bit addresses");
        // Table III: TA actions 20 kB, weights 12.5 kB per specialist.
        assert_eq!(s.ta_model_bytes(), 20_000);
        assert_eq!(s.weight_model_bytes(), 12_500);
        assert_eq!(s.model_bytes(), 32_500);
        // Complete model: 130 kB for four specialists.
        let total: usize = paper_specialists().iter().map(|s| s.model_bytes()).sum();
        assert_eq!(total, 130_000);
    }

    #[test]
    fn estimate_matches_section_6c() {
        let est = estimate(&paper_specialists(), &ScaleUpAssumptions::default());
        // ≈1020 paging cycles + 1000 processing → ≈2020/specialist,
        // ≈8080 total, ≈3440 FPS at 27.8 MHz.
        assert!((est.cycles_per_classification as f64 - 8080.0).abs() < 100.0);
        assert!(
            (est.rate_fps - 3440.0).abs() / 3440.0 < 0.03,
            "rate {:.0} FPS vs paper ≈3440",
            est.rate_fps
        );
        // R ≈ 5.8.
        assert!((est.r_ratio - 5.8).abs() < 0.05, "R = {:.2}", est.r_ratio);
        // Table III: 17.7 mm² (65 nm), 3.3 mm² (28 nm), 3.0 mW, 1.5 mW,
        // 0.9 µJ, 0.45 µJ.
        assert!((est.area_65nm_mm2 - 17.7).abs() < 0.3);
        assert!((est.area_28nm_mm2 - 3.3).abs() < 0.1);
        assert!((est.power_65nm_w - 3.0e-3).abs() < 0.05e-3);
        assert!((est.power_28nm_w - 1.5e-3).abs() < 0.03e-3);
        assert!((est.epc_65nm_j - 0.9e-6).abs() < 0.03e-6);
        assert!((est.epc_28nm_j - 0.45e-6).abs() < 0.02e-6);
        // Latency ≈ 0.3 ms (Table V).
        assert!((est.latency_s - 0.3e-3).abs() < 0.02e-3);
    }

    #[test]
    fn paging_width_trades_rate() {
        let mut a = ScaleUpAssumptions::default();
        let wide = estimate(&paper_specialists(), &a);
        a.model_xfer_bytes_per_cycle = 8;
        let narrow = estimate(&paper_specialists(), &a);
        assert!(narrow.rate_fps < wide.rate_fps);
        assert!(narrow.epc_65nm_j > wide.epc_65nm_j * 0.9);
    }
}
