//! Silicon-calibrated energy/power model (the software stand-in for the
//! paper's Joulescope measurements, §V / Table II).
//!
//! ## Calibration
//!
//! The paper's four core-power operating points are mutually consistent
//! with the standard decomposition `P(V,f) = P_leak(V) + E_cyc(V)·f`:
//!
//! | V     | f        | P        | ⇒ fit                                 |
//! |-------|----------|----------|----------------------------------------|
//! | 1.20 V| 27.8 MHz | 1.15 mW  | E_cyc(1.2)  = (1150−81)/26.8 ≈ 39.9 pJ |
//! | 1.20 V| 1.0 MHz  | 81 µW    | P_leak(1.2) = 81 − 39.9·1 ≈ 41 µW      |
//! | 0.82 V| 27.8 MHz | 0.52 mW  | E_cyc(0.82) = (520−21)/26.8 ≈ 18.6 pJ  |
//! | 0.82 V| 1.0 MHz  | 21 µW    | P_leak(0.82) ≈ 2.4 µW                  |
//!
//! `E_cyc(0.82)/E_cyc(1.2) = 0.467 ≈ (0.82/1.2)² = 0.467` — the dynamic
//! energy scales exactly with V², so a single effective capacitance
//! `C_eff ≈ 27.7 pF` describes the die.
//!
//! ## Decomposition
//!
//! The per-cycle dynamic energy is split over the simulator's activity
//! counters so that the two ablation claims reproduce:
//! - clock-gating off ⇒ +≈150% power at 27.8 MHz (§V: gating saves ≈60%);
//!   fitted through the per-DFF-clock energy and the ungated DFF-clock
//!   counts of the simulator;
//! - CSRF off ⇒ <1% power increase (§V): the clause AND-plane toggling
//!   carries a small per-toggle energy, consistent with §VII ("the
//!   combinational clause logic draws only a small amount of energy
//!   compared to the clock tree of the inference-core DFFs").

pub mod scaleup;
pub mod scaling;

use crate::asic::CycleReport;

/// An electrical operating point.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OperatingPoint {
    pub vdd: f64,
    pub freq_hz: f64,
}

impl OperatingPoint {
    /// §V measurement points.
    pub const FAST_1V2: OperatingPoint = OperatingPoint { vdd: 1.20, freq_hz: 27.8e6 };
    pub const FAST_0V82: OperatingPoint = OperatingPoint { vdd: 0.82, freq_hz: 27.8e6 };
    pub const SLOW_1V2: OperatingPoint = OperatingPoint { vdd: 1.20, freq_hz: 1.0e6 };
    pub const SLOW_0V82: OperatingPoint = OperatingPoint { vdd: 0.82, freq_hz: 1.0e6 };
}

/// Calibrated energy parameters at the reference voltage (1.2 V).
/// All energies in joules, powers in watts.
#[derive(Clone, Debug)]
pub struct EnergyModel {
    /// Reference voltage for the dynamic-energy constants.
    pub v_ref: f64,
    /// Always-on per-cycle energy at V_ref: control logic, the inference
    /// clock trunk and interconnect — the calibrated residual that
    /// dominates, as §VII observes.
    pub e_base_per_cycle: f64,
    /// Per DFF-clock event (leaf DFF + local clock branch) at V_ref.
    pub e_per_dff_clock: f64,
    /// Per clause combinational output toggle (AND-plane switch) at V_ref.
    pub e_per_clause_toggle: f64,
    /// Per adder-node evaluation in the class-sum tree at V_ref.
    pub e_per_adder_op: f64,
    /// Leakage anchors (paper fit).
    pub leak_at_1v2: f64,
    pub leak_at_0v82: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            v_ref: 1.2,
            // See module docs. The split is chosen so that with the
            // simulator's reference activity (gated, CSRF on, continuous
            // mode) the average is ≈39.9 pJ/cycle, the ungated run lands at
            // ≈2.5× dynamic power, and CSRF off costs <1%.
            e_base_per_cycle: 31.0e-12,
            e_per_dff_clock: 11.2e-15,
            e_per_clause_toggle: 30.0e-15,
            e_per_adder_op: 150.0e-15,
            leak_at_1v2: 41.0e-6,
            leak_at_0v82: 2.4e-6,
        }
    }
}

impl EnergyModel {
    /// Dynamic-energy voltage scale factor: (V/V_ref)².
    pub fn vscale(&self, vdd: f64) -> f64 {
        (vdd / self.v_ref).powi(2)
    }

    /// Leakage power at `vdd`, exponentially interpolated between the two
    /// measured anchors (sub-threshold leakage is exponential in V).
    pub fn leakage(&self, vdd: f64) -> f64 {
        let (v0, p0) = (0.82, self.leak_at_0v82);
        let (v1, p1) = (1.20, self.leak_at_1v2);
        let k = (p1 / p0).ln() / (v1 - v0);
        p0 * (k * (vdd - v0)).exp()
    }

    /// Dynamic energy of one classification from the simulator's report.
    pub fn dynamic_energy(&self, report: &CycleReport, vdd: f64) -> f64 {
        let cycles = report.phases.processing() as f64 + report.phases.transfer as f64;
        let e = self.e_base_per_cycle * cycles
            + self.e_per_dff_clock * report.total_dff_clocks() as f64
            + self.e_per_clause_toggle * report.clause_comb_toggles as f64
            + self.e_per_adder_op * report.adder_ops as f64;
        e * self.vscale(vdd)
    }

    /// Average core power while classifying back-to-back at `op`
    /// (the §V test mode: repeated classification of the test set).
    /// `report` must be a single-image continuous-mode report;
    /// `period_cycles` is the per-image period (372 pure, or the measured
    /// system period including processor overhead).
    pub fn power(&self, report: &CycleReport, op: OperatingPoint, period_cycles: f64) -> f64 {
        let e_img = self.dynamic_energy(report, op.vdd);
        let busy_cycles = report.phases.processing() as f64 + report.phases.transfer as f64;
        // Idle (overhead) cycles still clock the control logic.
        let idle_cycles = (period_cycles - busy_cycles).max(0.0);
        let e_idle = self.e_base_per_cycle * idle_cycles * self.vscale(op.vdd);
        self.leakage(op.vdd) + (e_img + e_idle) / period_cycles * op.freq_hz
    }

    /// Energy per classification at a given rate: P / rate.
    pub fn epc(&self, report: &CycleReport, op: OperatingPoint, period_cycles: f64) -> f64 {
        self.power(report, op, period_cycles) / (op.freq_hz / period_cycles)
    }
}

/// Measured system-level period at 27.8 MHz (§V: 60.3 k img/s ⇒ 461
/// cycles/img including system-processor overhead).
pub const SYSTEM_PERIOD_CYCLES_27M8: f64 = 27.8e6 / 60.3e3;
/// Measured system-level period at 1.0 MHz (§V: 2.27 k img/s).
pub const SYSTEM_PERIOD_CYCLES_1M: f64 = 1.0e6 / 2.27e3;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::asic::{Accelerator, ChipConfig};
    use crate::data::boolean::BoolImage;
    use crate::data::NUM_LITERALS;
    use crate::tm::{Model, Params};
    use crate::util::Xoshiro256ss;

    /// A representative model + image giving typical activity.
    fn reference_report(config: ChipConfig) -> CycleReport {
        let params = Params::asic();
        let mut rng = Xoshiro256ss::new(42);
        let mut m = Model::blank(params.clone());
        for j in 0..params.clauses {
            for _ in 0..6 {
                m.set_include(j, rng.usize_below(NUM_LITERALS), true);
            }
            for i in 0..params.classes {
                m.set_weight(i, j, (rng.below(41) as i32 - 20) as i8);
            }
        }
        let mut acc = Accelerator::new(params, config);
        acc.load_model(&m);
        let mut total = CycleReport::default();
        for s in 0..8 {
            let img = BoolImage::from_bools(
                &(0..784).map(|_| rng.chance(0.25)).collect::<Vec<bool>>(),
            );
            let r = acc.classify(&img, None, true).unwrap().report;
            total.accumulate(&r);
            let _ = s;
        }
        // Average back to a single image.
        let mut avg = total.clone();
        avg.phases = crate::asic::fsm::PhaseCycles::standard();
        avg.phases.transfer = 0;
        avg.window_dff_clocks /= 8;
        avg.clause_dff_clocks /= 8;
        avg.sum_pipe_dff_clocks /= 8;
        avg.image_buffer_dff_clocks /= 8;
        avg.control_dff_clocks /= 8;
        avg.model_dff_clocks /= 8;
        avg.clause_comb_toggles /= 8;
        avg.clause_evaluations /= 8;
        avg.adder_ops /= 8;
        avg
    }

    #[test]
    fn leakage_matches_anchors() {
        let m = EnergyModel::default();
        assert!((m.leakage(1.2) - 41e-6).abs() < 1e-9);
        assert!((m.leakage(0.82) - 2.4e-6).abs() < 1e-9);
        // Monotone in V.
        assert!(m.leakage(1.0) > m.leakage(0.9));
    }

    #[test]
    fn dynamic_scales_with_v_squared() {
        let m = EnergyModel::default();
        let r = reference_report(ChipConfig::default());
        let e12 = m.dynamic_energy(&r, 1.2);
        let e082 = m.dynamic_energy(&r, 0.82);
        assert!((e082 / e12 - (0.82f64 / 1.2).powi(2)).abs() < 1e-12);
    }

    #[test]
    fn reference_cycle_energy_near_39_9_pj() {
        // The calibration target: ≈39.9 pJ/cycle at 1.2 V, gated, CSRF on.
        let m = EnergyModel::default();
        let r = reference_report(ChipConfig::default());
        let per_cycle = m.dynamic_energy(&r, 1.2) / r.phases.processing() as f64;
        assert!(
            (per_cycle - 39.9e-12).abs() / 39.9e-12 < 0.10,
            "per-cycle dynamic {:.2} pJ vs 39.9 pJ",
            per_cycle * 1e12
        );
    }

    #[test]
    fn table2_power_points_within_tolerance() {
        let m = EnergyModel::default();
        let r = reference_report(ChipConfig::default());
        let cases = [
            (OperatingPoint::FAST_1V2, SYSTEM_PERIOD_CYCLES_27M8, 1.15e-3),
            (OperatingPoint::FAST_0V82, SYSTEM_PERIOD_CYCLES_27M8, 0.52e-3),
            (OperatingPoint::SLOW_1V2, SYSTEM_PERIOD_CYCLES_1M, 81e-6),
            (OperatingPoint::SLOW_0V82, SYSTEM_PERIOD_CYCLES_1M, 21e-6),
        ];
        for (op, period, expect) in cases {
            let p = m.power(&r, op, period);
            let err = (p - expect).abs() / expect;
            assert!(
                err < 0.12,
                "power at {:.2} V {:.1} MHz: model {:.3} mW vs paper {:.3} mW ({:.1}% off)",
                op.vdd,
                op.freq_hz / 1e6,
                p * 1e3,
                expect * 1e3,
                err * 100.0
            );
        }
    }

    #[test]
    fn table2_epc_points_within_tolerance() {
        let m = EnergyModel::default();
        let r = reference_report(ChipConfig::default());
        let cases = [
            (OperatingPoint::FAST_1V2, SYSTEM_PERIOD_CYCLES_27M8, 19.1e-9),
            (OperatingPoint::FAST_0V82, SYSTEM_PERIOD_CYCLES_27M8, 8.6e-9),
            (OperatingPoint::SLOW_1V2, SYSTEM_PERIOD_CYCLES_1M, 35.3e-9),
            (OperatingPoint::SLOW_0V82, SYSTEM_PERIOD_CYCLES_1M, 9.6e-9),
        ];
        for (op, period, expect) in cases {
            let e = m.epc(&r, op, period);
            let err = (e - expect).abs() / expect;
            assert!(
                err < 0.12,
                "EPC at {:.2} V {:.1} MHz: model {:.2} nJ vs paper {:.2} nJ",
                op.vdd,
                op.freq_hz / 1e6,
                e * 1e9,
                expect * 1e9
            );
        }
    }

    #[test]
    fn clock_gating_saves_about_60_percent() {
        let m = EnergyModel::default();
        let gated = reference_report(ChipConfig::default());
        let ungated = reference_report(ChipConfig {
            csrf: true,
            clock_gating: false,
        });
        let p_gated = m.power(&gated, OperatingPoint::FAST_1V2, SYSTEM_PERIOD_CYCLES_27M8);
        let p_ungated = m.power(&ungated, OperatingPoint::FAST_1V2, SYSTEM_PERIOD_CYCLES_27M8);
        let saving = 1.0 - p_gated / p_ungated;
        assert!(
            (0.50..0.70).contains(&saving),
            "§V: gating saves ≈60%, model says {:.1}%",
            saving * 100.0
        );
    }

    #[test]
    fn csrf_saves_less_than_one_percent() {
        let m = EnergyModel::default();
        let with = reference_report(ChipConfig::default());
        let without = reference_report(ChipConfig {
            csrf: false,
            clock_gating: true,
        });
        let p_with = m.power(&with, OperatingPoint::FAST_1V2, SYSTEM_PERIOD_CYCLES_27M8);
        let p_without = m.power(&without, OperatingPoint::FAST_1V2, SYSTEM_PERIOD_CYCLES_27M8);
        let saving = 1.0 - p_with / p_without;
        assert!(
            saving >= 0.0 && saving < 0.01,
            "§V: CSRF saves <1%, model says {:.2}%",
            saving * 100.0
        );
    }
}
