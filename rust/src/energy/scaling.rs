//! Technology scaling estimates (§VI-A): Dennard-style area scaling from
//! 65 nm to a target node, plus the literal-budget area reduction and the
//! paper's 28 nm power/EPC projections.

use crate::tm::Params;

/// A CMOS technology node.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TechNode {
    pub nm: f64,
    pub nominal_vdd: f64,
}

pub const NODE_65NM: TechNode = TechNode { nm: 65.0, nominal_vdd: 1.2 };
pub const NODE_28NM: TechNode = TechNode { nm: 28.0, nominal_vdd: 0.9 };

/// Dennard area scale factor between nodes: (target/source)².
pub fn area_scale(from: TechNode, to: TechNode) -> f64 {
    (to.nm / from.nm).powi(2)
}

/// The measured 65 nm die (Table II).
#[derive(Clone, Copy, Debug)]
pub struct DieFigures {
    pub core_area_mm2: f64,
    pub gate_count: u64,
    pub dffs: u64,
}

pub const ASIC_65NM: DieFigures = DieFigures {
    core_area_mm2: 2.7,
    gate_count: 201_000,
    dffs: 52_000,
};

/// §VI-A scaled design estimate: 28 nm + literal budget.
#[derive(Clone, Debug)]
pub struct ScaledEstimate {
    /// Core area after literal-budget reduction, still at 65 nm.
    pub area_65nm_budgeted_mm2: f64,
    /// Core area at the target node.
    pub area_target_mm2: f64,
    /// Power at 27.8 MHz at the target node/voltage.
    pub power_w: f64,
    /// EPC at the measured 60.3 k img/s system rate.
    pub epc_j: f64,
}

/// Reproduce the §VI-A arithmetic:
/// - the TA-action model part + clause logic ≈70% of core area;
/// - a `budget`-literal clause needs `budget × addr_bits` model bits vs
///   `literals`, shrinking that 70% share proportionally;
/// - Dennard area scaling to 28 nm;
/// - ≈50% power reduction vs the 0.82 V 65 nm chip at 0.7 V 28 nm.
pub fn scale_asic(
    params: &Params,
    budget: usize,
    power_65nm_0v82_w: f64,
    rate_img_s: f64,
) -> ScaledEstimate {
    // Fraction of the TA-action storage removed (paper: (272−90)/272 ≈ 67%).
    let addr_bits = crate::tm::budget::addr_bits(params.literals);
    let ta_reduction = 1.0 - (budget * addr_bits) as f64 / params.literals as f64;
    // TA part is ~70% of core area (§VI-A).
    const TA_AREA_SHARE: f64 = 0.70;
    let area_reduction = TA_AREA_SHARE * ta_reduction;
    let area_65 = ASIC_65NM.core_area_mm2 * (1.0 - area_reduction);
    let area_28 = area_65 * area_scale(NODE_65NM, NODE_28NM);
    // §VI-A: "roughly estimate a 50% reduction in power consumption
    // compared to the 65 nm chip operating at 0.82 V" (0.7 V, 28 nm).
    let power = power_65nm_0v82_w * 0.5;
    let epc = power / rate_img_s;
    ScaledEstimate {
        area_65nm_budgeted_mm2: area_65,
        area_target_mm2: area_28,
        power_w: power,
        epc_j: epc,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn area_scale_65_to_28() {
        let s = area_scale(NODE_65NM, NODE_28NM);
        assert!((s - (28.0f64 / 65.0).powi(2)).abs() < 1e-12);
        assert!((s - 0.1856).abs() < 1e-3);
    }

    #[test]
    fn paper_via_section_6a_numbers() {
        // Budget 10 literals → 90/272 of TA storage retained; total core
        // reduction ≈ 47%; 28 nm area ≈ 0.27 mm²; EPC ≈ 4.3 nJ.
        let est = scale_asic(&Params::asic(), 10, 0.52e-3, 60.3e3);
        let core_reduction = 1.0 - est.area_65nm_budgeted_mm2 / ASIC_65NM.core_area_mm2;
        assert!(
            (core_reduction - 0.47).abs() < 0.02,
            "core reduction {:.3} vs paper ≈0.47",
            core_reduction
        );
        assert!(
            (est.area_target_mm2 - 0.27).abs() < 0.02,
            "28 nm area {:.3} mm² vs paper 0.27 mm²",
            est.area_target_mm2
        );
        assert!(
            (est.epc_j - 4.3e-9).abs() < 0.2e-9,
            "28 nm EPC {:.2} nJ vs paper 4.3 nJ",
            est.epc_j * 1e9
        );
        assert!((est.power_w - 0.26e-3).abs() < 0.02e-3);
    }

    #[test]
    fn no_budget_means_no_area_saving_from_ta_part() {
        // With budget × addr_bits ≥ literals the "reduction" goes negative;
        // clamp-free arithmetic still reports it faithfully.
        let est = scale_asic(&Params::asic(), 31, 0.52e-3, 60.3e3);
        // 31 × 9 = 279 > 272 → slightly larger than dense.
        assert!(est.area_65nm_budgeted_mm2 > ASIC_65NM.core_area_mm2 * 0.99);
    }
}
