//! Figs. 7 & 8 + Table I — the accelerator's timing: FSM walk, the
//! 471-cycle single-image latency breakdown, the 372-cycle continuous-mode
//! period with transfer overlap, and the thermometer position encoding.
//!
//! Run: `cargo bench --bench fig8_timing`

use convcotm::asic::fsm::{self, State};
use convcotm::asic::{Accelerator, ChipConfig};
use convcotm::bench_harness::{section, FixtureSpec};
use convcotm::coordinator::SysProc;
use convcotm::data::{thermo, SynthFamily};
use convcotm::util::Table;

fn main() {
    section("Table I: thermometer position encoding (10×10 window in 28×28)");
    let mut t1 = Table::new(&["x or y position", "Thermometer encoded value (18 bits)"]);
    for v in [0usize, 1, 2, 16, 17, 18] {
        t1.row(&[format!("{v}"), thermo::to_table_string(v, 18)]);
    }
    println!("{}", t1.to_markdown());

    section("Fig. 7: accelerator FSM walk (single-shot then continuous)");
    let mut s = State::Idle;
    let mut trace = vec![format!("{s:?}")];
    for _ in 0..6 {
        s = fsm::next_state(s, false);
        trace.push(format!("{s:?}"));
    }
    println!("single-shot: {}", trace.join(" → "));
    let mut s = State::Output;
    println!(
        "continuous:  Output → {:?} (skips Idle/LoadImage — next frame already buffered)",
        fsm::next_state(s, true)
    );
    s = State::LoadModel;
    println!("load-model:  LoadModel → {:?}", fsm::next_state(s, false));

    section("Fig. 8: cycle-level timing (measured on the simulator)");
    let f = FixtureSpec::quick(SynthFamily::Digits).build();
    let mut acc = Accelerator::new(f.model.params.clone(), ChipConfig::default());
    acc.load_model(&f.model);

    let single = acc.classify(&f.test[0].0, None, false).unwrap();
    let p = &single.report.phases;
    let mut t = Table::new(&["Phase", "Cycles", "Notes"]);
    t.row(&[
        "Image transfer (AXI, byte/cycle)".into(),
        format!("{}", p.transfer),
        "98 data + 1 label byte".into(),
    ]);
    t.row(&[
        "Clause-register reset".into(),
        format!("{}", p.clause_reset),
        "Fig. 4 DFF reset".into(),
    ]);
    t.row(&[
        "Patch generation".into(),
        format!("{}", p.patches),
        "19×19 window positions".into(),
    ]);
    t.row(&[
        "Class-sum pipeline".into(),
        format!("{}", p.class_sum),
        "3-stage tree, gated (§IV-F)".into(),
    ]);
    t.row(&[
        "Argmax latch".into(),
        format!("{}", p.argmax),
        "Fig. 6 tree (combinational)".into(),
    ]);
    t.row(&[
        "Result/interrupt".into(),
        format!("{}", p.output),
        "prediction + label echo".into(),
    ]);
    t.row(&[
        "FSM transitions".into(),
        format!("{}", p.fsm_overhead),
        "state entry/exit".into(),
    ]);
    t.row(&[
        "TOTAL latency".into(),
        format!("{}", p.latency()),
        "paper: 471 cycles".into(),
    ]);
    println!("{}", t.to_markdown());
    assert_eq!(p.latency(), 471);

    // Continuous mode over N images.
    let n = 64;
    let images: Vec<_> = f.test.iter().take(n).map(|(i, _)| (i.clone(), None)).collect();
    let (results, cycles) = acc.run_continuous(&images).unwrap();
    println!(
        "continuous mode: {n} images in {cycles} cycles = 99 + {n}×372 → {} cycles/img steady-state",
        (cycles as usize - 99) / n
    );
    assert_eq!(cycles as usize, 99 + n * 372);
    assert_eq!(results.len(), n);

    let sp = SysProc;
    println!(
        "\npure accelerator bound @27.8 MHz: {:.1} k img/s; with system overhead: {:.1} k img/s (paper: 60.3 k)",
        27.8e6 / 372.0 / 1e3,
        sp.classification_rate(27.8e6) / 1e3
    );
    println!(
        "single-image latency @27.8 MHz incl. system overhead: {:.1} µs (paper: 25.4 µs)",
        sp.single_image_latency(27.8e6) * 1e6
    );
}
