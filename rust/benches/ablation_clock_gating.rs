//! Experiment X2 — the clock-gating ablation (§IV-F, §V): power with and
//! without clock gating at 27.8 MHz. Paper: gating reduces power ≈60%.
//!
//! Run: `cargo bench --bench ablation_clock_gating`

use convcotm::asic::{Accelerator, ChipConfig, CycleReport};
use convcotm::bench_harness::{fmt_power, section, FixtureSpec};
use convcotm::data::SynthFamily;
use convcotm::energy::{EnergyModel, OperatingPoint, SYSTEM_PERIOD_CYCLES_27M8};
use convcotm::util::Table;

fn run(clock_gating: bool, fixture: &convcotm::bench_harness::Fixture, n: usize) -> CycleReport {
    let mut acc = Accelerator::new(
        fixture.model.params.clone(),
        ChipConfig {
            csrf: true,
            clock_gating,
        },
    );
    acc.load_model(&fixture.model);
    let mut total = CycleReport::default();
    for (i, (img, _)) in fixture.test.iter().take(n).enumerate() {
        let r = acc.classify(img, None, i > 0).unwrap();
        total.accumulate(&r.report);
    }
    let mut avg = total;
    avg.phases = convcotm::asic::fsm::PhaseCycles::standard();
    avg.phases.transfer = 0;
    for v in [
        &mut avg.window_dff_clocks,
        &mut avg.clause_dff_clocks,
        &mut avg.sum_pipe_dff_clocks,
        &mut avg.image_buffer_dff_clocks,
        &mut avg.control_dff_clocks,
        &mut avg.model_dff_clocks,
        &mut avg.clause_comb_toggles,
        &mut avg.clause_evaluations,
        &mut avg.adder_ops,
    ] {
        *v /= n as u64;
    }
    avg
}

fn main() {
    section("Ablation X2: clock gating (§IV-F)");
    let fixture = if std::env::var("BENCH_QUICK").is_ok() {
        FixtureSpec::quick(SynthFamily::Digits).build()
    } else {
        FixtureSpec::standard(SynthFamily::Digits).build()
    };
    let n = fixture.test.len().min(200);

    let gated = run(true, &fixture, n);
    let ungated = run(false, &fixture, n);
    let em = EnergyModel::default();

    let mut t = Table::new(&["Operating point", "Gated", "Ungated", "Saving", "Paper"]);
    for (label, op, period) in [
        ("27.8 MHz, 1.20 V", OperatingPoint::FAST_1V2, SYSTEM_PERIOD_CYCLES_27M8),
        ("27.8 MHz, 0.82 V", OperatingPoint::FAST_0V82, SYSTEM_PERIOD_CYCLES_27M8),
    ] {
        let p_g = em.power(&gated, op, period);
        let p_u = em.power(&ungated, op, period);
        let saving = 1.0 - p_g / p_u;
        t.row(&[
            label.into(),
            fmt_power(p_g),
            fmt_power(p_u),
            format!("{:.1}%", saving * 100.0),
            "≈60%".into(),
        ]);
    }
    println!("{}", t.to_markdown());

    let mut td = Table::new(&["Component DFF clocks / image", "Gated", "Ungated"]);
    for (name, g, u) in [
        ("class-sum pipeline", gated.sum_pipe_dff_clocks, ungated.sum_pipe_dff_clocks),
        ("window array", gated.window_dff_clocks, ungated.window_dff_clocks),
        ("image buffer", gated.image_buffer_dff_clocks, ungated.image_buffer_dff_clocks),
        ("clause DFFs", gated.clause_dff_clocks, ungated.clause_dff_clocks),
        ("control", gated.control_dff_clocks, ungated.control_dff_clocks),
        ("model regs (domain stopped)", gated.model_dff_clocks, ungated.model_dff_clocks),
    ] {
        td.row(&[name.into(), format!("{g}"), format!("{u}")]);
    }
    println!("{}", td.to_markdown());

    let p_g = em.power(&gated, OperatingPoint::FAST_1V2, SYSTEM_PERIOD_CYCLES_27M8);
    let p_u = em.power(&ungated, OperatingPoint::FAST_1V2, SYSTEM_PERIOD_CYCLES_27M8);
    let saving = 1.0 - p_g / p_u;
    println!(
        "claim check: gating saves ≈60% at 27.8 MHz — {} ({:.1}%)",
        if (0.50..=0.70).contains(&saving) {
            "HOLDS"
        } else {
            "VIOLATED"
        },
        saving * 100.0
    );
    assert!((0.50..=0.70).contains(&saving));
}
