//! Table VI — overview of TM-based hardware solutions, with this work's
//! row regenerated from the model, plus the §VI-B on-device-training
//! extension estimate (experiment X5).
//!
//! Run: `cargo bench --bench table6_tm_hw_overview`

use convcotm::bench_harness::literature::{or_not_stated, table6_prior};
use convcotm::bench_harness::{fmt_energy, fmt_k, fmt_power, section};
use convcotm::coordinator::SysProc;
use convcotm::util::Table;

fn main() {
    section("Table VI: overview of TM-based hardware solutions");
    let sp = SysProc;
    let rate = sp.classification_rate(27.8e6);

    let mut t = Table::new(&[
        "Work",
        "Platform",
        "Algorithm",
        "Operation",
        "Dataset",
        "Accuracy",
        "Rate",
        "Power",
        "EPC",
    ]);
    t.row(&[
        "This work".into(),
        "ASIC 65 nm (modeled)".into(),
        "ConvCoTM".into(),
        "Inference".into(),
        "MNIST/FMNIST/KMNIST (synth subst.)".into(),
        "97.42/84.54/82.55% (paper)".into(),
        format!("{} img/s", fmt_k(rate)),
        fmt_power(0.52e-3),
        fmt_energy(8.6e-9),
    ]);
    for w in table6_prior() {
        t.row(&[
            w.label.into(),
            w.platform.into(),
            w.algorithm.into(),
            w.operation.into(),
            w.dataset.into(),
            w.accuracy_pct.into(),
            or_not_stated(w.rate_fps, |r| format!("{} img/s", fmt_k(r))),
            or_not_stated(w.power_w, |p| {
                if p > 1.0 {
                    format!("{p:.2} W")
                } else {
                    fmt_power(p)
                }
            }),
            or_not_stated(w.epc_j, fmt_energy),
        ]);
    }
    println!("{}", t.to_markdown());

    // Claim: lowest EPC among TM hardware with stated EPC... except the
    // simulated ReRAM IMC concept [35] at 13.9 nJ — ours is lower still.
    let ours = 8.6e-9;
    let better: Vec<_> = table6_prior()
        .into_iter()
        .filter(|w| w.epc_j.map(|e| e < ours).unwrap_or(false))
        .collect();
    println!(
        "claim check: lowest EPC among TM HW solutions with stated EPC — {}",
        if better.is_empty() {
            "HOLDS"
        } else {
            "VIOLATED"
        }
    );
    assert!(better.is_empty());

    section("§VI-B extension: on-device training estimate (X5)");
    // The FPGA in [12] trains 40k samples/s at 50 MHz; the same architecture
    // at this ASIC's 27.8 MHz scales to ≈22.2k samples/s.
    let fpga_rate = 40e3;
    let est = fpga_rate * 27.8e6 / 50e6;
    println!(
        "training throughput (FPGA-scaled): {} samples/s at 27.8 MHz (paper: ≈22.2k)",
        fmt_k(est)
    );
    assert!((est - 22.24e3).abs() < 50.0);
    // And from our §VI-B hardware model (asic::train_ext).
    use convcotm::asic::train_ext;
    use convcotm::tm::Params;
    let res = train_ext::resources(&Params::asic());
    let timing = train_ext::TrainTiming::standard(&Params::asic());
    println!(
        "hardware-model schedule: {} cycles/sample → {} samples/s at 27.8 MHz",
        timing.cycles_per_sample(),
        fmt_k(timing.samples_per_second(27.8e6))
    );
    println!(
        "resources: {} TA RAMs × {} rows ({} kb TAs), patch RAM {} kb, {} LFSRs, +{:.1} mm²",
        res.ta_rams,
        res.ta_ram_rows,
        res.ta_bits / 1024,
        res.patch_ram_bits / 1024,
        res.lfsrs,
        res.extra_area_mm2
    );
}
