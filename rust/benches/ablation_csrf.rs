//! Experiment X1 / F4 — the clause-switching-reduction feedback (CSRF)
//! ablation (§IV-D, §V): toggling of the combinational clause outputs with
//! and without the feedback, and the resulting power/EPC delta.
//!
//! Paper claims: ≈50% reduction in c_j^b toggling; <1% power reduction
//! (the clause comb logic is small next to the inference-core clock tree).
//!
//! Run: `cargo bench --bench ablation_csrf`

use convcotm::asic::{Accelerator, ChipConfig, CycleReport};
use convcotm::bench_harness::{section, FixtureSpec};
use convcotm::data::SynthFamily;
use convcotm::energy::{EnergyModel, OperatingPoint, SYSTEM_PERIOD_CYCLES_27M8};
use convcotm::util::Table;

fn run(csrf: bool, fixture: &convcotm::bench_harness::Fixture, n: usize) -> CycleReport {
    let mut acc = Accelerator::new(
        fixture.model.params.clone(),
        ChipConfig {
            csrf,
            clock_gating: true,
        },
    );
    acc.load_model(&fixture.model);
    let mut total = CycleReport::default();
    for (i, (img, _)) in fixture.test.iter().take(n).enumerate() {
        let r = acc.classify(img, None, i > 0).unwrap();
        total.accumulate(&r.report);
    }
    // Per-image average.
    let mut avg = total;
    avg.phases = convcotm::asic::fsm::PhaseCycles::standard();
    avg.phases.transfer = 0;
    for v in [
        &mut avg.window_dff_clocks,
        &mut avg.clause_dff_clocks,
        &mut avg.sum_pipe_dff_clocks,
        &mut avg.image_buffer_dff_clocks,
        &mut avg.control_dff_clocks,
        &mut avg.model_dff_clocks,
        &mut avg.clause_comb_toggles,
        &mut avg.clause_evaluations,
        &mut avg.adder_ops,
    ] {
        *v /= n as u64;
    }
    avg
}

fn main() {
    section("Ablation X1: clause switching reduction feedback (CSRF, Fig. 4)");
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let fixture = if quick {
        FixtureSpec::quick(SynthFamily::Digits).build()
    } else {
        FixtureSpec::standard(SynthFamily::Digits).build()
    };
    let n = fixture.test.len().min(if quick { 100 } else { 500 });

    let with = run(true, &fixture, n);
    let without = run(false, &fixture, n);

    let toggle_reduction =
        1.0 - with.clause_comb_toggles as f64 / without.clause_comb_toggles as f64;
    let eval_reduction = 1.0 - with.clause_evaluations as f64 / without.clause_evaluations as f64;

    let em = EnergyModel::default();
    let p_with = em.power(&with, OperatingPoint::FAST_1V2, SYSTEM_PERIOD_CYCLES_27M8);
    let p_without = em.power(&without, OperatingPoint::FAST_1V2, SYSTEM_PERIOD_CYCLES_27M8);
    let power_saving = 1.0 - p_with / p_without;

    let mut t = Table::new(&["Metric", "CSRF on", "CSRF off", "Reduction", "Paper"]);
    t.row(&[
        "c_j^b toggles / image".into(),
        format!("{}", with.clause_comb_toggles),
        format!("{}", without.clause_comb_toggles),
        format!("{:.1}%", toggle_reduction * 100.0),
        "≈50%".into(),
    ]);
    t.row(&[
        "clause evaluations / image".into(),
        format!("{}", with.clause_evaluations),
        format!("{}", without.clause_evaluations),
        format!("{:.1}%", eval_reduction * 100.0),
        "-".into(),
    ]);
    t.row(&[
        "core power @27.8 MHz, 1.2 V".into(),
        format!("{:.4} mW", p_with * 1e3),
        format!("{:.4} mW", p_without * 1e3),
        format!("{:.2}%", power_saving * 100.0),
        "<1%".into(),
    ]);
    println!("{}", t.to_markdown());

    println!(
        "claim check: toggle reduction ≈50% — {} ({:.1}%)",
        if (0.30..=0.75).contains(&toggle_reduction) {
            "HOLDS (shape)"
        } else {
            "VIOLATED"
        },
        toggle_reduction * 100.0
    );
    println!(
        "claim check: power saving <1% — {} ({:.2}%)",
        if power_saving >= 0.0 && power_saving < 0.01 {
            "HOLDS"
        } else {
            "VIOLATED"
        },
        power_saving * 100.0
    );
    assert!(toggle_reduction > 0.2, "CSRF must cut toggling substantially");
    assert!(power_saving >= 0.0 && power_saving < 0.01);
}
