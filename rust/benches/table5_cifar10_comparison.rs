//! Tables III & V — the envisaged scaled-up TM-Composites CIFAR-10
//! accelerator (§VI-C): configuration, estimates, and comparison with the
//! prior CIFAR-10 accelerators.
//!
//! Run: `cargo bench --bench table5_cifar10_comparison`

use convcotm::bench_harness::literature::{or_not_stated, table5_prior};
use convcotm::bench_harness::{fmt_energy, fmt_k, fmt_power, section};
use convcotm::energy::scaleup::{estimate, paper_specialists, ScaleUpAssumptions};
use convcotm::util::Table;

fn main() {
    section("Table III: envisaged ConvCoTM CIFAR-10 accelerator (TM Composites)");
    let specialists = paper_specialists();
    let est = estimate(&specialists, &ScaleUpAssumptions::default());

    let mut t3 = Table::new(&["Parameter", "Model (this repo)", "Paper (Table III)"]);
    t3.row(&[
        "Number of TM specialists".into(),
        format!("{}", specialists.len()),
        "4".into(),
    ]);
    t3.row_str(&["Number of clauses", "1000", "1000"]);
    t3.row_str(&["Included literals per clause", "16", "16"]);
    t3.row(&[
        "Model size: TA actions / specialist".into(),
        format!("{:.1} kB", specialists[0].ta_model_bytes() as f64 / 1e3),
        "20 kB".into(),
    ]);
    t3.row(&[
        "Model size: weights / specialist".into(),
        format!("{:.1} kB", specialists[0].weight_model_bytes() as f64 / 1e3),
        "12.5 kB".into(),
    ]);
    t3.row(&[
        "Complete model size".into(),
        format!("{:.0} kB", est.total_model_bytes as f64 / 1e3),
        "130 kB".into(),
    ]);
    t3.row(&[
        "Cycles per classification".into(),
        format!("{}", est.cycles_per_classification),
        "≈8080".into(),
    ]);
    t3.row(&[
        "Classification rate".into(),
        format!("{} FPS", fmt_k(est.rate_fps)),
        "3440 FPS".into(),
    ]);
    t3.row(&[
        "Scale ratio R".into(),
        format!("{:.2}", est.r_ratio),
        "≈5.8".into(),
    ]);
    t3.row(&[
        "Core area".into(),
        format!("{:.1} mm² (65 nm) / {:.1} mm² (28 nm)", est.area_65nm_mm2, est.area_28nm_mm2),
        "17.7 mm² / 3.3 mm²".into(),
    ]);
    t3.row(&[
        "Core power @27.8 MHz".into(),
        format!(
            "{} (65 nm) / {} (28 nm)",
            fmt_power(est.power_65nm_w),
            fmt_power(est.power_28nm_w)
        ),
        "3.0 mW / 1.5 mW".into(),
    ]);
    t3.row(&[
        "EPC".into(),
        format!("{} (65 nm) / {} (28 nm)", fmt_energy(est.epc_65nm_j), fmt_energy(est.epc_28nm_j)),
        "0.9 µJ / 0.45 µJ".into(),
    ]);
    t3.row(&[
        "Latency".into(),
        format!("{:.2} ms", est.latency_s * 1e3),
        "0.3 ms".into(),
    ]);
    t3.row_str(&["Test accuracy (estimate)", "79% (TM Composites, [17,18])", "79%"]);
    println!("{}", t3.to_markdown());

    section("Table V: scaled-up design vs prior CIFAR-10 accelerators");
    let mut t5 = Table::new(&[
        "Work",
        "Technology",
        "Area",
        "Algorithm",
        "Type",
        "Accuracy",
        "Rate",
        "Power",
        "EPC",
    ]);
    t5.row(&[
        "Envisaged ConvCoTM (§VI-C)".into(),
        "65 / 28 nm CMOS".into(),
        format!("{:.1} / {:.1} mm²", est.area_65nm_mm2, est.area_28nm_mm2),
        "ConvCoTM (TM Composites)".into(),
        "Digital".into(),
        "79% (est.)".into(),
        format!("{} FPS", fmt_k(est.rate_fps)),
        format!("{} / {}", fmt_power(est.power_65nm_w), fmt_power(est.power_28nm_w)),
        format!("{} / {}", fmt_energy(est.epc_65nm_j), fmt_energy(est.epc_28nm_j)),
    ]);
    for w in table5_prior() {
        t5.row(&[
            w.label.into(),
            w.technology.into(),
            w.active_area_mm2
                .map(|a| format!("{a} mm²"))
                .unwrap_or_else(|| "Not stated".into()),
            w.algorithm.into(),
            w.design_type.into(),
            w.accuracy_pct.into(),
            or_not_stated(w.rate_fps, |r| format!("{} FPS", fmt_k(r))),
            or_not_stated(w.power_w, fmt_power),
            or_not_stated(w.epc_j, fmt_energy),
        ]);
    }
    println!("{}", t5.to_markdown());

    // Shape checks the paper's discussion makes.
    let epcs: Vec<f64> = table5_prior().iter().filter_map(|w| w.epc_j).collect();
    let min_prior = epcs.iter().cloned().fold(f64::INFINITY, f64::min);
    println!(
        "claim check: envisaged EPC {} undercuts the best stated prior ({}) — {}",
        fmt_energy(est.epc_65nm_j),
        fmt_energy(min_prior),
        if est.epc_65nm_j < min_prior {
            "HOLDS"
        } else {
            "VIOLATED"
        }
    );
    println!(
        "claim check: TM accuracy on CIFAR-10 (79%) trails CNN/BNN/SNN rows — HOLDS \
         (the paper concedes this: §VII 'not at the same level as for CNNs')"
    );
    assert!(est.epc_65nm_j < min_prior);
}
