//! Table II — the accelerator's main characteristics and performance:
//! area/gate-count (die constants), power, classification rate, EPC and
//! latency at the four measured operating points, and test accuracy for
//! the three datasets (synthetic substitutes — DESIGN.md §5).
//!
//! Run: `cargo bench --bench table2_characteristics`
//! Env: BENCH_QUICK=1 for the small fixture.

use convcotm::asic::{dffs, Accelerator, ChipConfig, CycleReport};
use convcotm::bench_harness::{fmt_energy, fmt_k, fmt_power, section, FixtureSpec};
use convcotm::coordinator::SysProc;
use convcotm::data::SynthFamily;
use convcotm::energy::{
    EnergyModel, OperatingPoint, SYSTEM_PERIOD_CYCLES_1M, SYSTEM_PERIOD_CYCLES_27M8,
};
use convcotm::tm::Engine;
use convcotm::util::Table;

fn spec(family: SynthFamily) -> FixtureSpec {
    if std::env::var("BENCH_QUICK").is_ok() {
        FixtureSpec::quick(family)
    } else {
        FixtureSpec::standard(family)
    }
}

fn main() {
    section("Table II: ConvCoTM accelerator ASIC characteristics (reproduced)");

    // --- Accuracy rows (trained on the synthetic substitutes).
    let mut accuracies = Vec::new();
    let mut reference_report: Option<CycleReport> = None;
    for family in [SynthFamily::Digits, SynthFamily::Fashion, SynthFamily::Kana] {
        let f = spec(family).build();
        // ASIC-sim accuracy (bit-exact vs the SW engine — asserted in tests;
        // here we measure through the simulator to also collect activity).
        let mut acc = Accelerator::new(f.model.params.clone(), ChipConfig::default());
        acc.load_model(&f.model);
        let mut correct = 0usize;
        let mut report = CycleReport::default();
        for (i, (img, label)) in f.test.iter().enumerate() {
            let r = acc.classify(img, Some(*label), i > 0).unwrap();
            if r.prediction == *label {
                correct += 1;
            }
            report.accumulate(&r.report);
        }
        let n = f.test.len();
        // Average per-image activity for the energy model.
        let mut avg = report.clone();
        avg.phases = convcotm::asic::fsm::PhaseCycles::standard();
        avg.phases.transfer = 0;
        for v in [
            &mut avg.window_dff_clocks,
            &mut avg.clause_dff_clocks,
            &mut avg.sum_pipe_dff_clocks,
            &mut avg.image_buffer_dff_clocks,
            &mut avg.control_dff_clocks,
            &mut avg.model_dff_clocks,
            &mut avg.clause_comb_toggles,
            &mut avg.clause_evaluations,
            &mut avg.adder_ops,
        ] {
            *v /= n as u64;
        }
        if family == SynthFamily::Digits {
            reference_report = Some(avg);
        }
        let sw_acc = Engine::new().accuracy(&f.model, &f.test);
        accuracies.push((f.dataset.name.clone(), correct as f64 / n as f64, sw_acc, n));
    }

    let report = reference_report.expect("digits fixture ran");
    let em = EnergyModel::default();
    let sp = SysProc;

    let p_fast_12 = em.power(&report, OperatingPoint::FAST_1V2, SYSTEM_PERIOD_CYCLES_27M8);
    let p_fast_082 = em.power(&report, OperatingPoint::FAST_0V82, SYSTEM_PERIOD_CYCLES_27M8);
    let p_slow_12 = em.power(&report, OperatingPoint::SLOW_1V2, SYSTEM_PERIOD_CYCLES_1M);
    let p_slow_082 = em.power(&report, OperatingPoint::SLOW_0V82, SYSTEM_PERIOD_CYCLES_1M);
    let e_fast_12 = em.epc(&report, OperatingPoint::FAST_1V2, SYSTEM_PERIOD_CYCLES_27M8);
    let e_fast_082 = em.epc(&report, OperatingPoint::FAST_0V82, SYSTEM_PERIOD_CYCLES_27M8);
    let e_slow_12 = em.epc(&report, OperatingPoint::SLOW_1V2, SYSTEM_PERIOD_CYCLES_1M);
    let e_slow_082 = em.epc(&report, OperatingPoint::SLOW_0V82, SYSTEM_PERIOD_CYCLES_1M);

    let mut t = Table::new(&["Parameter", "Model (this repo)", "Paper (measured silicon)"]);
    t.row_str(&["Technology", "65 nm low-leakage CMOS (modeled)", "65 nm low-leakage CMOS (UMC)"]);
    t.row_str(&["Chip area (core)", "2.7 mm² (constant, calibration input)", "2.7 mm²"]);
    t.row(&[
        "Gatecount (core)".into(),
        format!("201k cells / {} DFFs (inventory)", dffs::TOTAL),
        "201k cells incl. 52k DFFs".into(),
    ]);
    t.row(&[
        "Power 27.8 MHz, 1.20 V".into(),
        fmt_power(p_fast_12),
        "1.15 mW".into(),
    ]);
    t.row(&[
        "Power 27.8 MHz, 0.82 V".into(),
        fmt_power(p_fast_082),
        "0.52 mW".into(),
    ]);
    t.row(&[
        "Power 1.0 MHz, 1.20 V".into(),
        fmt_power(p_slow_12),
        "81 µW".into(),
    ]);
    t.row(&[
        "Power 1.0 MHz, 0.82 V".into(),
        fmt_power(p_slow_082),
        "21 µW".into(),
    ]);
    t.row(&[
        "Classification rate 27.8 MHz".into(),
        format!("{} img/s", fmt_k(sp.classification_rate(27.8e6))),
        "60.3 k img/s".into(),
    ]);
    t.row(&[
        "Classification rate 1.0 MHz".into(),
        format!("{} img/s", fmt_k(sp.classification_rate(1.0e6))),
        "2.27 k img/s".into(),
    ]);
    t.row(&[
        "EPC 27.8 MHz, 1.20 V".into(),
        fmt_energy(e_fast_12),
        "19.1 nJ".into(),
    ]);
    t.row(&[
        "EPC 27.8 MHz, 0.82 V".into(),
        fmt_energy(e_fast_082),
        "8.6 nJ".into(),
    ]);
    t.row(&[
        "EPC 1.0 MHz, 1.20 V".into(),
        fmt_energy(e_slow_12),
        "35.3 nJ".into(),
    ]);
    t.row(&[
        "EPC 1.0 MHz, 0.82 V".into(),
        fmt_energy(e_slow_082),
        "9.6 nJ".into(),
    ]);
    t.row(&[
        "Latency (single image, 27.8 MHz)".into(),
        format!("{:.1} µs", sp.single_image_latency(27.8e6) * 1e6),
        "25.4 µs".into(),
    ]);
    t.row(&[
        "Latency (single image, 1.0 MHz)".into(),
        format!("{:.2} ms", sp.single_image_latency(1.0e6) * 1e3),
        "0.66 ms".into(),
    ]);
    for (name, asic_acc, sw_acc, n) in &accuracies {
        let paper = match name.as_str() {
            "synth-mnist" => "97.42% (MNIST)",
            "synth-fmnist" => "84.54% (FMNIST)",
            "synth-kmnist" => "82.55% (KMNIST)",
            _ => "-",
        };
        t.row(&[
            format!("Test accuracy [{name}] (n={n})"),
            format!("{:.2}% (ASIC sim) = {:.2}% (SW)", asic_acc * 100.0, sw_acc * 100.0),
            paper.into(),
        ]);
    }
    println!("{}", t.to_markdown());
    println!(
        "note: accuracy rows use the procedural synthetic datasets (no network \
         access); the ASIC-sim and SW columns must agree exactly, reproducing \
         the paper's §V bit-exactness claim. Power/EPC/rate come from the \
         toggle-accurate simulator driving the silicon-calibrated energy model."
    );
}
