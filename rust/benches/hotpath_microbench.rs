//! Hot-path microbenchmarks (the §Perf instrument): native engine
//! throughput (compiled-plan and legacy paths), ASIC-simulator speed, PJRT
//! artifact throughput (batch 1 and 16), trainer throughput (per-sample
//! and data-parallel epochs at 1 vs 4 threads, with the modeled §VI-B
//! on-device rate for comparison), coordinator batching overhead, and
//! end-to-end rows through the HTTP front door (`serve http (1 shard)` /
//! `(4 shards)` + the derived `http_overhead_us`).
//!
//! Targets (DESIGN.md §7): native ≥60.3 k img/s single core; compiled plan
//! ≥1.5× the mask-scan early-exit path with 0 heap allocations per image;
//! ASIC sim ≥1 M cycles/s; coordinator overhead <10 µs p50.
//!
//! Besides the markdown table, the run writes machine-readable
//! `BENCH_hotpath.json` next to the manifest (override with the
//! `BENCH_JSON` env var) so the perf trajectory is tracked in CI from one
//! PR to the next.
//!
//! Run: `cargo bench --bench hotpath_microbench` (`BENCH_QUICK=1` for the
//! CI-sized run).

use convcotm::asic::{Accelerator, ChipConfig};
use convcotm::bench_harness::{fmt_k, section, CountingAllocator, FixtureSpec};
use convcotm::coordinator::{
    Backend, BatchConfig, Coordinator, ModelRegistry, NativeBackend, PoolConfig,
};
use convcotm::data::SynthFamily;
use convcotm::tm::{BlockEval, ClausePlan, Engine, EvalScratch, Trainer};
use convcotm::util::json::Json;
use convcotm::util::stats::Summary;
use convcotm::util::Table;
use std::time::{Duration, Instant};

// Count every heap allocation so the zero-alloc invariant of the
// compiled-plan path is *measured*, not assumed.
#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// One measured row, mirrored into the markdown table and the JSON file.
struct Row {
    label: String,
    img_per_s: f64,
    us_per_img: f64,
    allocs_per_img: Option<f64>,
}

fn bench_budget() -> Duration {
    Duration::from_millis(if std::env::var("BENCH_QUICK").is_ok() {
        300
    } else {
        1500
    })
}

fn throughput(
    label: &str,
    t: &mut Table,
    rows: &mut Vec<Row>,
    images_per_iter: usize,
    mut f: impl FnMut(),
) -> f64 {
    // Warmup (also grows any lazily sized buffers).
    f();
    let budget = bench_budget();
    let a0 = CountingAllocator::allocations();
    let start = Instant::now();
    let mut iters = 0usize;
    while start.elapsed() < budget {
        f();
        iters += 1;
    }
    let secs = start.elapsed().as_secs_f64();
    let a1 = CountingAllocator::allocations();
    let rate = (iters * images_per_iter) as f64 / secs;
    let allocs = (a1 - a0) as f64 / (iters * images_per_iter) as f64;
    t.row(&[
        label.into(),
        format!("{} img/s", fmt_k(rate)),
        format!("{:.2} µs/img", 1e6 / rate),
        format!("{allocs:.1} allocs/img"),
    ]);
    rows.push(Row {
        label: label.to_string(),
        img_per_s: rate,
        us_per_img: 1e6 / rate,
        allocs_per_img: Some(allocs),
    });
    rate
}

/// End-to-end rows through the network front door: 4 keep-alive client
/// threads × batch-16 classify calls against a loopback HTTP server over
/// a 1- then 4-shard pool. Returns the two rates plus the single-inflight
/// batch-1 p50 (µs) measured on the 1-shard server, from which
/// `http_overhead_us` is derived.
fn bench_http_rows(
    model: &convcotm::tm::Model,
    images: &[convcotm::data::BoolImage],
    t: &mut Table,
    rows: &mut Vec<Row>,
) -> (Vec<f64>, f64) {
    use convcotm::server::http::write_request;
    use convcotm::server::{HttpConn, HttpServer, Limits, ServerConfig, ServerState};
    use std::net::TcpStream;
    use std::sync::Arc;

    let quick = std::env::var("BENCH_QUICK").is_ok();
    let clients = 4usize;
    let batch = 16usize;
    let reqs_per_client = if quick { 40 } else { 150 };

    // One request body, serialized once and replayed (the server parses
    // it fresh every time — that parse cost is what these rows measure).
    let refs: Vec<&convcotm::data::BoolImage> = images.iter().take(batch).collect();
    let body = convcotm::server::proto::classify_request_body(None, &refs);
    let one_body = convcotm::server::proto::classify_request_body(None, &refs[..1]);

    let exchange = |conn: &mut HttpConn<TcpStream>, body: &[u8]| {
        write_request(conn.get_mut(), "POST", "/v1/classify", body, true).expect("write");
        let resp = conn
            .read_response(&Limits::default())
            .expect("response")
            .expect("server open");
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    };

    let mut rates = Vec::new();
    let mut single_p50_us = 0.0f64;
    for shards in [1usize, 4] {
        let coord = Arc::new(Coordinator::start_pool(
            ModelRegistry::single("bench", model.clone()),
            PoolConfig {
                shards,
                queue_capacity: 4096,
                batch: BatchConfig {
                    max_batch: 16,
                    max_wait: Duration::from_micros(50),
                },
                ..PoolConfig::default()
            },
        ));
        let state = ServerState::new(Arc::clone(&coord));
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            http_workers: clients,
            ..ServerConfig::default()
        };
        let server = HttpServer::start(&cfg, Arc::clone(&state)).expect("bind loopback");
        let addr = server.local_addr();
        let connect = || {
            let s = TcpStream::connect(addr).expect("connect");
            s.set_nodelay(true).expect("nodelay");
            HttpConn::new(s)
        };

        // Warmup sizes every shard arena and worker buffer.
        exchange(&mut connect(), &body);
        let a0 = CountingAllocator::allocations();
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for _ in 0..clients {
                let (body, connect, exchange) = (&body, &connect, &exchange);
                scope.spawn(move || {
                    let mut conn = connect();
                    for _ in 0..reqs_per_client {
                        exchange(&mut conn, body);
                    }
                });
            }
        });
        let secs = t0.elapsed().as_secs_f64();
        let served = (clients * reqs_per_client * batch) as f64;
        let allocs = (CountingAllocator::allocations() - a0) as f64 / served;
        let rate = served / secs;
        let label = if shards == 1 {
            "serve http (1 shard)".to_string()
        } else {
            format!("serve http ({shards} shards)")
        };
        t.row(&[
            label.clone(),
            format!("{} img/s", fmt_k(rate)),
            format!("{:.2} µs/img", 1e6 / rate),
            format!("{allocs:.1} allocs/img"),
        ]);
        rows.push(Row {
            label,
            img_per_s: rate,
            us_per_img: 1e6 / rate,
            allocs_per_img: Some(allocs),
        });
        rates.push(rate);

        if shards == 1 {
            // Single-inflight batch-1 latency → http_overhead_us.
            let n = if quick { 150 } else { 400 };
            let mut conn = connect();
            exchange(&mut conn, &one_body);
            let mut lats = Vec::with_capacity(n);
            for _ in 0..n {
                let r0 = Instant::now();
                exchange(&mut conn, &one_body);
                lats.push(r0.elapsed().as_secs_f64() * 1e6);
            }
            single_p50_us = Summary::of(&lats).p50;
        }

        server.request_shutdown();
        server.join();
        drop(state);
        if let Ok(coord) = Arc::try_unwrap(coord) {
            coord.shutdown();
        }
    }
    (rates, single_p50_us)
}

/// Tier rows: the event-loop acceptance load (16 keep-alive connections
/// on 4 workers — holding more connections than workers is exactly what
/// the readiness loop buys) and the route tier (one router fronting two
/// replicas, traffic rendezvous-split across both owners).
fn bench_tier_rows(
    model: &convcotm::tm::Model,
    images: &[convcotm::data::BoolImage],
    t: &mut Table,
    rows: &mut Vec<Row>,
) {
    use convcotm::server::http::write_request;
    use convcotm::server::router::{
        rank_replicas, spawn_health_checker, RouterConfig, RouterState,
    };
    use convcotm::server::{HttpConn, HttpServer, Limits, ServerConfig, ServerState};
    use std::net::{SocketAddr, TcpStream};
    use std::sync::Arc;

    let quick = std::env::var("BENCH_QUICK").is_ok();
    let batch = 16usize;
    let refs: Vec<&convcotm::data::BoolImage> = images.iter().take(batch).collect();

    let start_replica = |names: &[&str]| {
        let registry = ModelRegistry::new();
        for name in names {
            registry.insert(name, model.clone()).expect("servable model");
        }
        let coord = Arc::new(Coordinator::start_pool(
            Arc::new(registry),
            PoolConfig {
                shards: 1,
                queue_capacity: 4096,
                batch: BatchConfig {
                    max_batch: 16,
                    max_wait: Duration::from_micros(50),
                },
                ..PoolConfig::default()
            },
        ));
        let state = ServerState::new(Arc::clone(&coord));
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            http_workers: 4,
            ..ServerConfig::default()
        };
        let server = HttpServer::start(&cfg, Arc::clone(&state)).expect("bind loopback");
        (server, state, coord)
    };
    let stop = |server: HttpServer, state: Arc<ServerState>, coord: Arc<Coordinator>| {
        server.request_shutdown();
        server.join();
        drop(state);
        if let Ok(coord) = Arc::try_unwrap(coord) {
            coord.shutdown();
        }
    };
    let connect = |addr: SocketAddr| {
        let s = TcpStream::connect(addr).expect("connect");
        s.set_nodelay(true).expect("nodelay");
        HttpConn::new(s)
    };
    let exchange = |conn: &mut HttpConn<TcpStream>, body: &[u8]| {
        write_request(conn.get_mut(), "POST", "/v1/classify", body, true).expect("write");
        let resp = conn
            .read_response(&Limits::default())
            .expect("response")
            .expect("server open");
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    };
    let emit = |label: &str, rate: f64, t: &mut Table, rows: &mut Vec<Row>| {
        t.row(&[
            label.into(),
            format!("{} img/s", fmt_k(rate)),
            format!("{:.2} µs/img", 1e6 / rate),
            "—".into(),
        ]);
        rows.push(Row {
            label: label.to_string(),
            img_per_s: rate,
            us_per_img: 1e6 / rate,
            allocs_per_img: None,
        });
    };

    // Row 1: 16 keep-alive connections on 4 HTTP workers. Before the
    // event-loop redesign this shape meant 16 blocked threads; now the
    // parked 12 cost a slab slot each while 4 workers drain the ready set.
    {
        let conns = 16usize;
        let reqs_per_conn = if quick { 25 } else { 80 };
        let (server, state, coord) = start_replica(&["bench"]);
        let addr = server.local_addr();
        let body = convcotm::server::proto::classify_request_body(Some("bench"), &refs);
        exchange(&mut connect(addr), &body);
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for _ in 0..conns {
                let (body, connect, exchange) = (&body, &connect, &exchange);
                scope.spawn(move || {
                    let mut conn = connect(addr);
                    for _ in 0..reqs_per_conn {
                        exchange(&mut conn, body);
                    }
                });
            }
        });
        let rate = (conns * reqs_per_conn * batch) as f64 / t0.elapsed().as_secs_f64();
        emit("serve http (event loop)", rate, t, rows);
        stop(server, state, coord);
    }

    // Row 2: the same load through a router fronting two replicas, the
    // traffic split across two models whose rendezvous owners differ —
    // both replicas serve, and the row prices the extra forwarding hop.
    {
        let names: Vec<String> = (0..16).map(|i| format!("bench-{i}")).collect();
        let name_refs: Vec<&str> = names.iter().map(String::as_str).collect();
        let (srv_a, state_a, coord_a) = start_replica(&name_refs);
        let (srv_b, state_b, coord_b) = start_replica(&name_refs);
        let (addr_a, addr_b) = (srv_a.local_addr().to_string(), srv_b.local_addr().to_string());
        let router_state = RouterState::new(RouterConfig {
            replicas: vec![addr_a.clone(), addr_b.clone()],
            health_interval: Duration::from_millis(100),
            ..RouterConfig::default()
        })
        .expect("router state");
        let health = spawn_health_checker(Arc::clone(&router_state));
        let router = HttpServer::start(
            &ServerConfig {
                addr: "127.0.0.1:0".to_string(),
                http_workers: 4,
                ..ServerConfig::default()
            },
            Arc::clone(&router_state),
        )
        .expect("bind router");
        let router_addr = router.local_addr();

        // One model homed on each replica (16 candidates make a single-
        // sided split vanishingly unlikely; fall back to any name if so).
        let addrs = [addr_a.as_str(), addr_b.as_str()];
        let pick = |owner: usize| {
            names
                .iter()
                .find(|n| rank_replicas(n, &addrs)[0] == owner)
                .unwrap_or(&names[0])
                .clone()
        };
        let bodies: Vec<Vec<u8>> = [pick(0), pick(1)]
            .iter()
            .map(|n| convcotm::server::proto::classify_request_body(Some(n), &refs))
            .collect();

        let clients = 4usize;
        let reqs_per_client = if quick { 40 } else { 150 };
        exchange(&mut connect(router_addr), &bodies[0]);
        exchange(&mut connect(router_addr), &bodies[1]);
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for c in 0..clients {
                let (bodies, connect, exchange) = (&bodies, &connect, &exchange);
                scope.spawn(move || {
                    let mut conn = connect(router_addr);
                    let body = &bodies[c % 2];
                    for _ in 0..reqs_per_client {
                        exchange(&mut conn, body);
                    }
                });
            }
        });
        let rate = (clients * reqs_per_client * batch) as f64 / t0.elapsed().as_secs_f64();
        emit("route (2 replicas)", rate, t, rows);

        router.request_shutdown();
        router.join();
        health.join().expect("health checker");
        stop(srv_a, state_a, coord_a);
        stop(srv_b, state_b, coord_b);
    }
}

/// Traced (disarmed hooks) vs untraced hot path. The traced run performs
/// the full per-request observability sequence the server executes when
/// tracing is *disarmed* — mint an id, open the scope, one `Instant` read
/// (the unconditional parse clock), three `record_stage` early-returns,
/// close the scope — amortized over a batch-16 request, exactly like the
/// front door. The overhead must stay ≤1% (`check_bench.py` gates
/// `trace_overhead_pct`); `tests/obs_alloc.rs` holds the allocation half
/// of the same claim. Median of alternating fixed-work trials, so a
/// scheduler hiccup in one trial cannot fake a regression.
fn bench_trace_overhead(
    model: &convcotm::tm::Model,
    images: &[convcotm::data::BoolImage],
    t: &mut Table,
    rows: &mut Vec<Row>,
) -> f64 {
    use convcotm::obs::{self, Stage, TraceId};
    assert!(!obs::armed(), "benches measure the disarmed discipline");
    let engine = Engine::new();
    let plan = ClausePlan::compile(model);
    let mut scratch = EvalScratch::new();
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let n_images = if quick { 4_000 } else { 20_000 };
    let batch = 16usize;

    let untraced = |scratch: &mut EvalScratch| {
        let t0 = Instant::now();
        for i in 0..n_images {
            let img = &images[i % images.len()];
            std::hint::black_box(engine.classify_with(&plan, img, scratch));
        }
        t0.elapsed().as_secs_f64()
    };
    let traced = |scratch: &mut EvalScratch| {
        let t0 = Instant::now();
        let mut i = 0usize;
        while i < n_images {
            obs::begin_request(TraceId::mint());
            let parse_t0 = Instant::now();
            obs::record_stage(Stage::Parse, parse_t0.elapsed().as_secs_f64() * 1e6);
            let end = (i + batch).min(n_images);
            while i < end {
                let img = &images[i % images.len()];
                std::hint::black_box(engine.classify_with(&plan, img, scratch));
                i += 1;
            }
            obs::record_stage(Stage::QueueWait, 0.0);
            obs::record_stage(Stage::Eval, 0.0);
            obs::end_request(200);
        }
        t0.elapsed().as_secs_f64()
    };

    // Warm both shapes, then alternate fixed-work trials.
    let _ = untraced(&mut scratch);
    let _ = traced(&mut scratch);
    let trials = if quick { 3 } else { 5 };
    let mut overheads = Vec::with_capacity(trials);
    let (mut last_u, mut last_t) = (0.0f64, 0.0f64);
    for _ in 0..trials {
        last_u = untraced(&mut scratch);
        last_t = traced(&mut scratch);
        overheads.push((last_t - last_u) / last_u * 100.0);
    }
    overheads.sort_by(f64::total_cmp);
    let pct = overheads[overheads.len() / 2];

    for (label, secs) in [
        ("classify untraced (no hooks)", last_u),
        ("classify traced (disarmed hooks)", last_t),
    ] {
        let rate = n_images as f64 / secs;
        t.row(&[
            label.into(),
            format!("{} img/s", fmt_k(rate)),
            format!("{:.2} µs/img", 1e6 / rate),
            "—".into(),
        ]);
        rows.push(Row {
            label: label.to_string(),
            img_per_s: rate,
            us_per_img: 1e6 / rate,
            allocs_per_img: None,
        });
    }
    pct
}

fn main() {
    section("Hot-path microbenchmarks (§Perf)");
    let fixture = FixtureSpec::quick(SynthFamily::Digits).build();
    let images: Vec<_> = fixture.test.iter().map(|(i, _)| i.clone()).collect();
    let model = fixture.model.clone();

    let mut t = Table::new(&["Path", "Throughput", "Per image", "Heap"]);
    let mut rows: Vec<Row> = Vec::new();

    // Native engine through the compiled clause plan + arena (the §Perf
    // serving path). The acceptance bar: ≥1.5× the mask-scan early-exit
    // row below, at exactly 0 allocs/img in steady state.
    let engine = Engine::new();
    let plan = ClausePlan::compile(&model);
    let mut scratch = EvalScratch::new();
    let mut idx0 = 0usize;
    let plan_rate = throughput("native engine (compiled plan)", &mut t, &mut rows, 1, || {
        let img = &images[idx0 % images.len()];
        idx0 += 1;
        std::hint::black_box(engine.classify_with(&plan, img, &mut scratch));
    });
    let plan_allocs = rows.last().and_then(|r| r.allocs_per_img).unwrap_or(f64::NAN);

    // Image-major blocked evaluation (tm::block): each clause's CSR row is
    // walked once per 32-image block and literal tests land on 64 image
    // lanes per word op. Acceptance bar: ≥1.5× the compiled-plan row at
    // exactly 0 allocs/img (the block arena is grown once by the warmup).
    let block = BlockEval::compile(&plan);
    let ref_blocks: Vec<Vec<&convcotm::data::BoolImage>> = images
        .chunks(32)
        .filter(|c| c.len() == 32)
        .map(|c| c.iter().collect())
        .collect();
    let mut blk = 0usize;
    let blocked_rate = throughput("native engine (blocked B=32)", &mut t, &mut rows, 32, || {
        let refs = &ref_blocks[blk % ref_blocks.len()];
        blk += 1;
        std::hint::black_box(engine.classify_block_with(&block, refs, 32, &mut scratch));
    });
    let blocked_allocs = rows.last().and_then(|r| r.allocs_per_img).unwrap_or(f64::NAN);

    // Native engine, mask-scan early-exit (the pre-plan fast path).
    let mut idx = 0usize;
    let native_rate = throughput("native engine (early-exit)", &mut t, &mut rows, 1, || {
        let img = &images[idx % images.len()];
        idx += 1;
        std::hint::black_box(engine.classify(&model, img));
    });

    // Native engine, exhaustive per-patch evaluation (the oracle).
    let slow_engine = Engine { early_exit: false };
    let mut idx2 = 0usize;
    throughput("native engine (exhaustive)", &mut t, &mut rows, 1, || {
        let img = &images[idx2 % images.len()];
        idx2 += 1;
        std::hint::black_box(slow_engine.classify(&model, img));
    });

    // ASIC simulator. Cycles come from the accelerator's own geometry-
    // derived report (372/image for the ASIC shape in continuous mode;
    // strided and CIFAR fixtures report their actual figures).
    let mut acc = Accelerator::new(model.params.clone(), ChipConfig::default());
    acc.load_model(&model);
    let mut idx3 = 0usize;
    let t_sim = Instant::now();
    let mut sim_iters = 0usize;
    let mut sim_cycles_total = 0u64;
    let sim_budget = bench_budget();
    while t_sim.elapsed() < sim_budget {
        let img = &images[idx3 % images.len()];
        idx3 += 1;
        let res = acc.classify(img, None, true).unwrap();
        sim_cycles_total += res.report.phases.latency() as u64;
        std::hint::black_box(res);
        sim_iters += 1;
    }
    let sim_secs = t_sim.elapsed().as_secs_f64();
    let sim_rate = sim_iters as f64 / sim_secs;
    let sim_cycles_rate = sim_cycles_total as f64 / sim_secs;
    t.row(&[
        "ASIC simulator".into(),
        format!("{} img/s", fmt_k(sim_rate)),
        format!("{:.2} M sim-cycles/s", sim_cycles_rate / 1e6),
        "—".into(),
    ]);
    rows.push(Row {
        label: "ASIC simulator".into(),
        img_per_s: sim_rate,
        us_per_img: 1e6 / sim_rate,
        allocs_per_img: None,
    });

    // Batch classification through the NativeBackend: serial vs parallel
    // over the batch (the coordinator's multi-core path).
    {
        let refs: Vec<&convcotm::data::BoolImage> = images.iter().collect();
        let mut serial = NativeBackend::with_threads(model.clone(), 1);
        throughput(
            &format!("NativeBackend batch={} (1 thread)", refs.len()),
            &mut t,
            &mut rows,
            refs.len(),
            || {
                std::hint::black_box(serial.classify(&refs).unwrap());
            },
        );
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let mut parallel = NativeBackend::with_threads(model.clone(), cores);
        throughput(
            &format!("NativeBackend batch={} ({cores} threads)", refs.len()),
            &mut t,
            &mut rows,
            refs.len(),
            || {
                std::hint::black_box(parallel.classify(&refs).unwrap());
            },
        );
        // The allocation-free blocked core: borrowed predictions, no
        // per-image output materialization (`classify_block`).
        let mut blocked_backend = NativeBackend::with_threads(model.clone(), 1);
        throughput(
            &format!("NativeBackend batch={} (blocked)", refs.len()),
            &mut t,
            &mut rows,
            refs.len(),
            || {
                std::hint::black_box(blocked_backend.classify_block(&refs).unwrap());
            },
        );
    }

    // Serve path: end-to-end through the shard pool (bounded queues,
    // least-outstanding routing, registry resolution) on a 64-image
    // concurrent workload — the rows CI tracks for shard scaling.
    let mut pool_rates = Vec::new();
    for shards in [1usize, 4] {
        let coord = Coordinator::start_pool(
            ModelRegistry::single("bench", model.clone()),
            PoolConfig {
                shards,
                queue_capacity: 4096,
                batch: BatchConfig {
                    max_batch: 16,
                    max_wait: Duration::from_micros(50),
                },
                ..PoolConfig::default()
            },
        );
        let workload: Vec<_> = images.iter().cycle().take(64).cloned().collect();
        let label = if shards == 1 {
            "serve pool (1 shard)".to_string()
        } else {
            format!("serve pool ({shards} shards)")
        };
        let rate = throughput(&label, &mut t, &mut rows, workload.len(), || {
            let rxs: Vec<_> = workload.iter().map(|img| coord.submit(img.clone())).collect();
            for rx in rxs {
                rx.recv().unwrap().unwrap();
            }
        });
        pool_rates.push(rate);
        coord.shutdown();
    }

    // Serve path through the full network front door: keep-alive HTTP
    // clients against the loopback server over the same shard pool — the
    // end-to-end rows CI tracks for the transport layer, plus the
    // single-inflight latency that yields `http_overhead_us`.
    let (http_rates, http_p50_us) = bench_http_rows(&model, &images, &mut t, &mut rows);

    // Event-loop and route-tier rows (the ISSUE-8 front-door acceptance
    // shapes): many keep-alive connections on few workers, and the same
    // load through a 2-replica route tier.
    bench_tier_rows(&model, &images, &mut t, &mut rows);

    // Traced vs untraced: the disarmed per-request hook sequence amortized
    // over batch-16 requests must be free to within the ≤1% CI gate.
    let trace_overhead_pct = bench_trace_overhead(&model, &images, &mut t, &mut rows);

    // PJRT artifacts.
    #[cfg(feature = "pjrt")]
    let artifact_dir =
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    #[cfg(feature = "pjrt")]
    if artifact_dir.join("convcotm_b1.hlo.txt").exists() {
        use convcotm::runtime::ModelInputs;
        let mi = ModelInputs::from_model(&model);
        let mut rt = convcotm::runtime::Runtime::new(&artifact_dir).unwrap();
        {
            let g1 = rt.load("convcotm_b1", 1).unwrap();
            let mut i = 0usize;
            throughput("PJRT artifact (batch 1)", &mut t, &mut rows, 1, || {
                let img = &images[i % images.len()];
                i += 1;
                std::hint::black_box(g1.run(&[img], &mi).unwrap());
            });
        }
        {
            let g16 = rt.load("convcotm_b16", 16).unwrap();
            let refs: Vec<&convcotm::data::BoolImage> = images.iter().take(16).collect();
            throughput("PJRT artifact (batch 16)", &mut t, &mut rows, 16, || {
                std::hint::black_box(g16.run(&refs, &mi).unwrap());
            });
        }
    } else {
        eprintln!("(PJRT rows skipped: run `make artifacts`)");
    }

    // Trainer throughput (the §VI-B substrate; plan-synced + arena-backed,
    // so steady-state updates are allocation-free). A full warmup epoch
    // grows every arena and the plan's CSR high-water mark first, so this
    // row *measures* the per-sample zero-alloc invariant (its baseline
    // pins 0.0 allocs/img in BENCH_baseline.json) instead of cold-start
    // buffer growth.
    let mut trainer = Trainer::new(model.params.clone(), 7);
    trainer.epoch(&fixture.train, 0);
    let mut i = 0usize;
    throughput("trainer (update/sample)", &mut t, &mut rows, 1, || {
        let (img, label) = &fixture.train[i % fixture.train.len()];
        i += 1;
        trainer.update(img, *label);
    });

    // Data-parallel training engine: full epochs at 1 vs 4 worker threads
    // (the models are bit-identical by construction — tested in
    // tests/train_parallel.rs; here only the throughput is measured).
    let mut train_rates = Vec::new();
    for threads in [1usize, 4] {
        let mut tr = Trainer::new(model.params.clone(), 7);
        tr.set_threads(threads);
        let label = if threads == 1 {
            "train (1 thread)".to_string()
        } else {
            format!("train ({threads} threads)")
        };
        let mut e = 0usize;
        let rate = throughput(&label, &mut t, &mut rows, fixture.train.len(), || {
            tr.epoch(&fixture.train, e);
            e += 1;
        });
        train_rates.push(rate);
    }
    let train_speedup = train_rates[1] / train_rates[0];

    println!("{}", t.to_markdown());
    println!(
        "compiled plan vs early-exit: {:.2}× (target ≥1.5×) at {:.1} allocs/img (target 0) — {}",
        plan_rate / native_rate,
        plan_allocs,
        if plan_rate >= 1.5 * native_rate && plan_allocs == 0.0 {
            "HOLDS"
        } else {
            "MISSED"
        }
    );
    let block_speedup = blocked_rate / plan_rate;
    println!(
        "blocked B=32 vs compiled plan: {block_speedup:.2}× (target ≥1.5×) at \
         {blocked_allocs:.1} allocs/img (target 0) — {}",
        if block_speedup >= 1.5 && blocked_allocs == 0.0 {
            "HOLDS"
        } else {
            "MISSED"
        }
    );
    println!(
        "traced vs untraced (disarmed hooks, batch-16 amortized): {trace_overhead_pct:+.3}% \
         (gate ≤1%) — {}",
        if trace_overhead_pct <= 1.0 { "HOLDS" } else { "MISSED" }
    );
    let pool_speedup = pool_rates[1] / pool_rates[0];
    println!(
        "shard pool 4 vs 1: {pool_speedup:.2}× on {} core(s) (tests/serving_pool.rs asserts ≥2× with ≥4 cores)",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    );
    // Training scaling + the §VI-B hardware gap: the modeled on-device
    // training extension vs this software trainer, tracked per run.
    let hw_rate = convcotm::asic::train_ext::TrainTiming::standard(&model.params)
        .samples_per_second(27.8e6);
    println!(
        "train 4 vs 1 threads: {train_speedup:.2}× (target ≥2.0 on ≥4 cores); \
         sw {} vs modeled §VI-B hw {} samples/s → {:.2}× of on-device rate",
        fmt_k(train_rates[1]),
        fmt_k(hw_rate),
        train_rates[1] / hw_rate
    );

    // Coordinator batching overhead: compare direct engine latency with
    // end-to-end coordinator latency under a single-inflight load.
    section("Coordinator overhead");
    let coord = Coordinator::start(
        Box::new(NativeBackend::new(model.clone())),
        BatchConfig {
            max_batch: 16,
            max_wait: Duration::from_micros(50),
        },
    );
    let mut lats = Vec::new();
    for img in images.iter().cycle().take(400) {
        let t0 = Instant::now();
        coord.classify(img.clone()).unwrap();
        lats.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let snap = coord.shutdown();
    let direct_us = 1e6 / plan_rate;
    let s = Summary::of(&lats);
    println!(
        "end-to-end p50 {:.1} µs (direct engine {:.1} µs) → overhead {:.1} µs; p99 {:.1} µs; batches formed: {}",
        s.p50,
        direct_us,
        (s.p50 - direct_us).max(0.0),
        s.p99,
        snap.batches
    );
    println!(
        "target check: overhead <10 µs p50 — {}",
        if (s.p50 - direct_us) < 10.0 {
            "HOLDS"
        } else {
            "MISSED"
        }
    );
    // HTTP transport overhead: single-inflight batch-1 p50 through the
    // front door, minus the coordinator's own end-to-end p50 (so the
    // figure isolates parse + socket + response serialization).
    let http_overhead_us = (http_p50_us - s.p50).max(0.0);
    println!(
        "http front door: single-inflight p50 {:.1} µs (coordinator {:.1} µs) → \
         http_overhead_us {:.1}; pool-over-http 4 vs 1 shards: {:.2}×",
        http_p50_us,
        s.p50,
        http_overhead_us,
        http_rates[1] / http_rates[0]
    );

    // PJRT coordinator end-to-end (thread-affine backend via factory).
    #[cfg(feature = "pjrt")]
    if artifact_dir.join("convcotm_b16.hlo.txt").exists() {
        use convcotm::coordinator::PjrtBackend;
        let m2 = model.clone();
        let dir2 = artifact_dir.clone();
        let coord = Coordinator::start_with(
            move || PjrtBackend::new(&dir2, "convcotm_b16", 16, &m2).unwrap(),
            BatchConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(200),
            },
        );
        let t0 = Instant::now();
        let n = 256;
        let rxs: Vec<_> = images.iter().cycle().take(n).map(|i| coord.submit(i.clone())).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let rate = n as f64 / t0.elapsed().as_secs_f64();
        let snap = coord.shutdown();
        println!(
            "PJRT serving pipeline: {} img/s across {} batches (batch-16 artifact)",
            fmt_k(rate),
            snap.batches
        );
    }

    // Machine-readable trajectory: BENCH_hotpath.json (CI uploads it).
    let json = Json::obj([
        ("bench", Json::str("hotpath_microbench")),
        ("fixture", Json::str("synth-digits quick (300 train / 100 test)")),
        ("geometry", Json::str(model.params.geometry.to_string())),
        ("quick", Json::Bool(std::env::var("BENCH_QUICK").is_ok())),
        (
            "sim_cycles_per_s",
            Json::num(sim_cycles_rate),
        ),
        (
            "plan_speedup_vs_early_exit",
            Json::num(plan_rate / native_rate),
        ),
        ("block_speedup_vs_plan", Json::num(block_speedup)),
        ("pool_speedup_4v1_shards", Json::num(pool_speedup)),
        ("http_overhead_us", Json::num(http_overhead_us)),
        ("trace_overhead_pct", Json::num(trace_overhead_pct)),
        ("http_speedup_4v1_shards", Json::num(http_rates[1] / http_rates[0])),
        ("train_speedup_4v1", Json::num(train_speedup)),
        ("train_hw_samples_per_s_27m8", Json::num(hw_rate)),
        ("train_sw_over_hw_4t", Json::num(train_rates[1] / hw_rate)),
        (
            "rows",
            Json::arr(rows.iter().map(|r| {
                Json::obj([
                    ("path", Json::str(r.label.clone())),
                    ("img_per_s", Json::num(r.img_per_s)),
                    ("us_per_img", Json::num(r.us_per_img)),
                    (
                        "allocs_per_img",
                        r.allocs_per_img.map(Json::num).unwrap_or(Json::Null),
                    ),
                ])
            })),
        ),
    ]);
    let out_path = std::env::var("BENCH_JSON").unwrap_or_else(|_| {
        format!("{}/BENCH_hotpath.json", env!("CARGO_MANIFEST_DIR"))
    });
    match std::fs::write(&out_path, json.to_string_pretty() + "\n") {
        Ok(()) => println!("\nwrote {out_path}"),
        Err(e) => eprintln!("\nfailed to write {out_path}: {e}"),
    }
}
