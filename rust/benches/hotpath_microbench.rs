//! Hot-path microbenchmarks (the §Perf instrument): native engine
//! throughput, ASIC-simulator speed, PJRT artifact throughput (batch 1 and
//! 16), trainer throughput and coordinator batching overhead.
//!
//! Targets (DESIGN.md §7): native ≥60.3 k img/s single core; ASIC sim
//! ≥1 M cycles/s; coordinator overhead <10 µs p50.
//!
//! Run: `cargo bench --bench hotpath_microbench`

use convcotm::asic::{Accelerator, ChipConfig};
use convcotm::bench_harness::{fmt_k, section, FixtureSpec};
use convcotm::coordinator::{Backend, BatchConfig, Coordinator, NativeBackend};
use convcotm::data::SynthFamily;
use convcotm::tm::{Engine, Trainer};
use convcotm::util::stats::Summary;
use convcotm::util::Table;
use std::time::{Duration, Instant};

fn throughput(label: &str, t: &mut Table, images_per_iter: usize, mut f: impl FnMut()) -> f64 {
    // Warmup.
    f();
    let budget = Duration::from_millis(
        if std::env::var("BENCH_QUICK").is_ok() { 300 } else { 1500 },
    );
    let start = Instant::now();
    let mut iters = 0usize;
    while start.elapsed() < budget {
        f();
        iters += 1;
    }
    let secs = start.elapsed().as_secs_f64();
    let rate = (iters * images_per_iter) as f64 / secs;
    t.row(&[
        label.into(),
        format!("{} img/s", fmt_k(rate)),
        format!("{:.2} µs/img", 1e6 / rate),
    ]);
    rate
}

fn main() {
    section("Hot-path microbenchmarks (§Perf)");
    let fixture = FixtureSpec::quick(SynthFamily::Digits).build();
    let images: Vec<_> = fixture.test.iter().map(|(i, _)| i.clone()).collect();
    let model = fixture.model.clone();

    let mut t = Table::new(&["Path", "Throughput", "Per image"]);

    // Native engine, early-exit on (the CSRF analogue).
    let engine = Engine::new();
    let mut idx = 0usize;
    let native_rate = throughput("native engine (early-exit)", &mut t, 1, || {
        let img = &images[idx % images.len()];
        idx += 1;
        std::hint::black_box(engine.classify(&model, img));
    });

    // Native engine, exhaustive.
    let slow_engine = Engine { early_exit: false };
    let mut idx2 = 0usize;
    throughput("native engine (exhaustive)", &mut t, 1, || {
        let img = &images[idx2 % images.len()];
        idx2 += 1;
        std::hint::black_box(slow_engine.classify(&model, img));
    });

    // ASIC simulator.
    let mut acc = Accelerator::new(model.params.clone(), ChipConfig::default());
    acc.load_model(&model);
    let mut idx3 = 0usize;
    let t_sim = Instant::now();
    let mut sim_iters = 0usize;
    while t_sim.elapsed() < Duration::from_millis(800) {
        let img = &images[idx3 % images.len()];
        idx3 += 1;
        std::hint::black_box(acc.classify(img, None, true).unwrap());
        sim_iters += 1;
    }
    let sim_secs = t_sim.elapsed().as_secs_f64();
    let sim_rate = sim_iters as f64 / sim_secs;
    let sim_cycles_rate = sim_rate * 372.0;
    t.row(&[
        "ASIC simulator".into(),
        format!("{} img/s", fmt_k(sim_rate)),
        format!("{:.2} M sim-cycles/s", sim_cycles_rate / 1e6),
    ]);

    // Batch classification through the NativeBackend: serial vs parallel
    // over the batch (the coordinator's multi-core path).
    {
        let refs: Vec<&convcotm::data::BoolImage> = images.iter().collect();
        let mut serial = NativeBackend::with_threads(model.clone(), 1);
        throughput(
            &format!("NativeBackend batch={} (1 thread)", refs.len()),
            &mut t,
            refs.len(),
            || {
                std::hint::black_box(serial.classify(&refs).unwrap());
            },
        );
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let mut parallel = NativeBackend::with_threads(model.clone(), cores);
        throughput(
            &format!("NativeBackend batch={} ({cores} threads)", refs.len()),
            &mut t,
            refs.len(),
            || {
                std::hint::black_box(parallel.classify(&refs).unwrap());
            },
        );
    }

    // PJRT artifacts.
    #[cfg(feature = "pjrt")]
    let artifact_dir =
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    #[cfg(feature = "pjrt")]
    if artifact_dir.join("convcotm_b1.hlo.txt").exists() {
        use convcotm::runtime::ModelInputs;
        let mi = ModelInputs::from_model(&model);
        let mut rt = convcotm::runtime::Runtime::new(&artifact_dir).unwrap();
        {
            let g1 = rt.load("convcotm_b1", 1).unwrap();
            let mut i = 0usize;
            throughput("PJRT artifact (batch 1)", &mut t, 1, || {
                let img = &images[i % images.len()];
                i += 1;
                std::hint::black_box(g1.run(&[img], &mi).unwrap());
            });
        }
        {
            let g16 = rt.load("convcotm_b16", 16).unwrap();
            let refs: Vec<&convcotm::data::BoolImage> = images.iter().take(16).collect();
            throughput("PJRT artifact (batch 16)", &mut t, 16, || {
                std::hint::black_box(g16.run(&refs, &mi).unwrap());
            });
        }
    } else {
        eprintln!("(PJRT rows skipped: run `make artifacts`)");
    }

    // Trainer throughput (the §VI-B substrate).
    let mut trainer = Trainer::new(model.params.clone(), 7);
    let mut i = 0usize;
    throughput("trainer (update/sample)", &mut t, 1, || {
        let (img, label) = &fixture.train[i % fixture.train.len()];
        i += 1;
        trainer.update(img, *label);
    });

    println!("{}", t.to_markdown());

    // Coordinator batching overhead: compare direct engine latency with
    // end-to-end coordinator latency under a single-inflight load.
    section("Coordinator overhead");
    let coord = Coordinator::start(
        Box::new(NativeBackend::new(model.clone())),
        BatchConfig {
            max_batch: 16,
            max_wait: Duration::from_micros(50),
        },
    );
    let mut lats = Vec::new();
    for img in images.iter().cycle().take(400) {
        let t0 = Instant::now();
        coord.classify(img.clone()).unwrap();
        lats.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let snap = coord.shutdown();
    let direct_us = 1e6 / native_rate;
    let s = Summary::of(&lats);
    println!(
        "end-to-end p50 {:.1} µs (direct engine {:.1} µs) → overhead {:.1} µs; p99 {:.1} µs; batches formed: {}",
        s.p50,
        direct_us,
        (s.p50 - direct_us).max(0.0),
        s.p99,
        snap.batches
    );
    println!(
        "target check: overhead <10 µs p50 — {}",
        if (s.p50 - direct_us) < 10.0 { "HOLDS" } else { "MISSED" }
    );

    // PJRT coordinator end-to-end (thread-affine backend via factory).
    #[cfg(feature = "pjrt")]
    if artifact_dir.join("convcotm_b16.hlo.txt").exists() {
        use convcotm::coordinator::PjrtBackend;
        let m2 = model.clone();
        let dir2 = artifact_dir.clone();
        let coord = Coordinator::start_with(
            move || PjrtBackend::new(&dir2, "convcotm_b16", 16, &m2).unwrap(),
            BatchConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(200),
            },
        );
        let t0 = Instant::now();
        let n = 256;
        let rxs: Vec<_> = images.iter().cycle().take(n).map(|i| coord.submit(i.clone())).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let rate = n as f64 / t0.elapsed().as_secs_f64();
        let snap = coord.shutdown();
        println!(
            "PJRT serving pipeline: {} img/s across {} batches (batch-16 artifact)",
            fmt_k(rate),
            snap.batches
        );
    }
}
