//! Table IV — comparison with prior ULP MNIST accelerators, plus the
//! envisaged 28 nm scaled design (§VI-A, experiment X4).
//!
//! Literature rows are the published figures from the paper's own table;
//! "this work" rows are regenerated from our simulator + energy model.
//!
//! Run: `cargo bench --bench table4_mnist_comparison`

use convcotm::bench_harness::literature::{or_not_stated, table4_prior};
use convcotm::bench_harness::{fmt_energy, fmt_k, fmt_power, section};
use convcotm::coordinator::SysProc;
use convcotm::energy::scaling::{scale_asic, ASIC_65NM};
use convcotm::tm::Params;
use convcotm::util::Table;

fn main() {
    section("Table IV: comparison with prior ULP MNIST accelerators");
    let sp = SysProc;
    let rate = sp.classification_rate(27.8e6);
    let rate_1m = sp.classification_rate(1.0e6);

    // This work (65 nm, modeled at the measured operating points).
    let power_082 = 0.52e-3; // reproduced by table2 bench within tolerance
    let scaled = scale_asic(&Params::asic(), 10, power_082, rate);

    let mut t = Table::new(&[
        "Work",
        "Technology",
        "Area",
        "Algorithm",
        "Type",
        "Accuracy (MNIST)",
        "Rate",
        "Power",
        "EPC",
    ]);
    t.row(&[
        "This work (65 nm)".into(),
        "65 nm CMOS".into(),
        format!("{:.1} mm²", ASIC_65NM.core_area_mm2),
        "ConvCoTM".into(),
        "Digital".into(),
        "97.42% (paper) / synth substitute here".into(),
        format!("{} / {}", fmt_k(rate), fmt_k(rate_1m)),
        "1.15 / 0.52 mW; 81 / 21 µW".into(),
        "19.1 / 8.6 / 35.3 / 9.6 nJ".into(),
    ]);
    t.row(&[
        "This work scaled (28 nm, §VI-A)".into(),
        "28 nm CMOS".into(),
        format!("{:.2} mm²", scaled.area_target_mm2),
        "ConvCoTM (10-literal budget)".into(),
        "Digital".into(),
        "97.42% (unchanged model family)".into(),
        fmt_k(rate),
        fmt_power(scaled.power_w),
        fmt_energy(scaled.epc_j),
    ]);
    for w in table4_prior() {
        t.row(&[
            w.label.into(),
            w.technology.into(),
            w.active_area_mm2
                .map(|a| format!("{a} mm²"))
                .unwrap_or_else(|| "Not stated".into()),
            w.algorithm.into(),
            w.design_type.into(),
            w.accuracy_pct.into(),
            or_not_stated(w.rate_fps, fmt_k),
            or_not_stated(w.power_w, fmt_power),
            or_not_stated(w.epc_j, fmt_energy),
        ]);
    }
    println!("{}", t.to_markdown());

    // The paper's headline ordering claims, checked mechanically.
    let ours = 8.6e-9;
    let mut all: Vec<(String, f64)> = table4_prior()
        .into_iter()
        .filter_map(|w| w.epc_j.map(|e| (w.label.to_string(), e)))
        .collect();
    all.push(("This work (0.82 V)".into(), ours));
    all.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
    println!("EPC ranking (lower is better):");
    for (i, (label, e)) in all.iter().enumerate() {
        println!("  {}. {} — {}", i + 1, label, fmt_energy(*e));
    }
    let our_rank = all.iter().position(|(l, _)| l.starts_with("This work")).unwrap() + 1;
    println!(
        "\nclaim check: this work ranks #{our_rank} (paper: second most energy-efficient, \
         lowest among fully digital) — {}",
        if our_rank == 2 { "HOLDS" } else { "VIOLATED" }
    );
    assert_eq!(our_rank, 2, "paper's ranking claim must reproduce");
    println!(
        "claim check: 28 nm scaled EPC {} ≈ paper's 4.3 nJ estimate, close to \
         Zhao [20]'s 3.32 nJ — {}",
        fmt_energy(scaled.epc_j),
        if (scaled.epc_j - 4.3e-9).abs() < 0.3e-9 {
            "HOLDS"
        } else {
            "VIOLATED"
        }
    );
}
