//! End-to-end tests for the sharded multi-model serving stack: shard-pool
//! throughput scaling, lossless hot-swap under load, bounded-queue load
//! shedding, clean-shutdown draining and per-request failure isolation.

use convcotm::coordinator::{
    Backend, BackendOutput, BatchConfig, Coordinator, ModelRegistry, PoolConfig, ShardHealth,
    ShardPanicked, SupervisorConfig,
};
use convcotm::data::{BoolImage, Geometry};
use convcotm::tm::{Engine, Model, Params};
use convcotm::util::fault::{self, FaultPlan};
use convcotm::util::Xoshiro256ss;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Every test in this binary takes this guard: the throughput and
/// load-shedding tests are timing-sensitive, and the default parallel
/// test runner must not let the others steal their cores mid-measurement.
static HEAVY: Mutex<()> = Mutex::new(());

fn heavy_guard() -> std::sync::MutexGuard<'static, ()> {
    HEAVY.lock().unwrap_or_else(|e| e.into_inner())
}

fn random_model(seed: u64, includes_per_clause: usize) -> Model {
    let params = Params::asic();
    let mut rng = Xoshiro256ss::new(seed);
    let mut m = Model::blank(params.clone());
    for j in 0..params.clauses {
        for _ in 0..1 + rng.usize_below(includes_per_clause) {
            m.set_include(j, rng.usize_below(params.literals), true);
        }
        for i in 0..params.classes {
            m.set_weight(i, j, (rng.below(61) as i32 - 30) as i8);
        }
    }
    m
}

fn random_images(seed: u64, n: usize) -> Vec<BoolImage> {
    let mut rng = Xoshiro256ss::new(seed);
    (0..n)
        .map(|_| BoolImage::from_bools(&(0..784).map(|_| rng.chance(0.3)).collect::<Vec<_>>()))
        .collect()
}

/// A model that deterministically predicts `class` on a blank image: one
/// clause over a negated content literal (true on every patch of a blank
/// image) voting +5 for `class`.
fn fixed_class_model(class: usize) -> Model {
    let p = Params::asic();
    let mut m = Model::blank(p.clone());
    m.set_include(0, p.geometry.num_features(), true);
    m.set_weight(class, 0, 5);
    m
}

fn pool(model: &Model, shards: usize, queue_capacity: usize) -> Coordinator {
    Coordinator::start_pool(
        ModelRegistry::single("m", model.clone()),
        PoolConfig {
            shards,
            queue_capacity,
            batch: BatchConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(50),
            },
            ..PoolConfig::default()
        },
    )
}

/// Best-of-3 end-to-end throughput of a concurrent workload (submit all,
/// then collect) through a pool.
fn measure_throughput(coord: &Coordinator, images: &[BoolImage], reps: usize) -> f64 {
    // Warmup sizes every shard's arena.
    for img in images.iter().take(8) {
        coord.classify(img.clone()).unwrap();
    }
    let mut best = 0.0f64;
    for _ in 0..reps {
        let t0 = Instant::now();
        let rxs: Vec<_> = images.iter().map(|i| coord.submit(i.clone())).collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        best = best.max(images.len() as f64 / t0.elapsed().as_secs_f64());
    }
    best
}

/// Acceptance (a): a 4-shard pool is ≥2× single-shard throughput on a
/// ≥64-image concurrent workload. The bar scales with the machine (and
/// with BENCH_QUICK, mirroring the CI bench): a 4-way-parallel assertion
/// is only meaningful with ≥4 cores; on 2–3 cores any real speedup is
/// accepted, and a single-core host only checks correctness.
#[test]
fn four_shards_at_least_double_single_shard_throughput() {
    let _serial = heavy_guard();
    let quick = std::env::var("BENCH_QUICK").is_ok();
    let n_images = if quick { 128 } else { 256 };
    let reps = if quick { 2 } else { 3 };
    let model = random_model(42, 6);
    let images = random_images(43, n_images);

    let single = pool(&model, 1, 4096);
    let rate1 = measure_throughput(&single, &images, reps);
    assert_eq!(single.shutdown().errors, 0);

    let quad = pool(&model, 4, 4096);
    let rate4 = measure_throughput(&quad, &images, reps);
    assert_eq!(quad.shutdown().errors, 0);

    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let speedup = rate4 / rate1;
    println!("pool speedup 4 vs 1 shards: {speedup:.2}x on {cores} cores");
    if cores >= 4 {
        assert!(
            speedup >= 2.0,
            "4 shards must be >=2x 1 shard on a >=4-core host, got {speedup:.2}x \
             ({rate1:.0} vs {rate4:.0} img/s over {n_images} images)"
        );
    } else if cores >= 2 {
        assert!(
            speedup >= 1.1,
            "4 shards must beat 1 shard on a {cores}-core host, got {speedup:.2}x"
        );
    }
}

/// Acceptance (b): hot-swapping a model under load loses zero in-flight
/// requests, and post-swap responses reflect the new weights.
#[test]
fn hot_swap_under_load_is_lossless_and_takes_effect() {
    let _serial = heavy_guard();
    let registry = ModelRegistry::single("live", fixed_class_model(2));
    let coord = Coordinator::start_pool(
        Arc::clone(&registry),
        PoolConfig {
            shards: 2,
            queue_capacity: 1024,
            batch: BatchConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(50),
            },
            ..PoolConfig::default()
        },
    );
    let img = BoolImage::blank();
    let mut rxs = Vec::new();
    // Load the pool, then flip the model while those requests are in
    // flight, then keep submitting.
    for _ in 0..200 {
        rxs.push(coord.submit_to(Some("live"), img.clone()));
    }
    let swapped = registry.swap("live", fixed_class_model(7)).unwrap();
    assert_eq!(swapped.version, 2);
    for _ in 0..200 {
        rxs.push(coord.submit_to(Some("live"), img.clone()));
    }
    let predictions: Vec<u8> = rxs
        .into_iter()
        .map(|rx| rx.recv().expect("no request dropped").unwrap().prediction)
        .collect();
    // Zero dropped, zero failed — and every response came from one of the
    // two model versions, never a half-built plan.
    assert_eq!(predictions.len(), 400);
    assert!(predictions.iter().all(|&p| p == 2 || p == 7));
    // Requests submitted after swap() returned are batched after the Arc
    // flip, so they must all see the new weights.
    assert!(
        predictions[200..].iter().all(|&p| p == 7),
        "post-swap submissions served by the old model"
    );
    let snap = coord.shutdown();
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.requests, 400);
    assert_eq!(snap.per_model["live"].requests, 400);
}

/// Acceptance (c): an overwhelmed pool sheds load with a typed
/// `Overloaded` error instead of queuing without limit.
#[test]
fn bounded_queue_sheds_with_overloaded_instead_of_growing() {
    let _serial = heavy_guard();
    let model = random_model(5, 6);
    let coord = pool(&model, 1, 64);
    let img = random_images(6, 1).remove(0);
    let mut accepted = Vec::new();
    let mut shed = 0usize;
    // Burst far past the queue bound: submission is ~20-30x faster than
    // evaluation, so a 64-deep queue must fill and shed.
    for _ in 0..5000 {
        match coord.try_submit(img.clone()) {
            Ok(rx) => accepted.push(rx),
            Err(e) => {
                assert_eq!((e.shards, e.capacity), (1, 64));
                shed += 1;
            }
        }
    }
    assert!(shed > 0, "a 5000-request burst against a 64-deep queue must shed");
    // Every *accepted* request still completes successfully.
    for rx in accepted {
        rx.recv().unwrap().unwrap();
    }
    let snap = coord.shutdown();
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.requests as usize + shed, 5000);
}

/// A backend that parks inside `classify` until released — makes the
/// full-queue state deterministic for the backpressure test.
struct GateBackend {
    geometry: Geometry,
    gate: std::sync::mpsc::Receiver<()>,
}

impl Backend for GateBackend {
    fn name(&self) -> &'static str {
        "gate"
    }
    fn max_batch(&self) -> usize {
        1
    }
    fn geometry(&self) -> Geometry {
        self.geometry
    }
    fn classify(&mut self, imgs: &[&BoolImage]) -> anyhow::Result<Vec<BackendOutput>> {
        // Block until the test releases one batch (after shutdown the gate
        // sender is gone; serve the drain immediately).
        let _ = self.gate.recv();
        Ok(imgs
            .iter()
            .map(|_| BackendOutput {
                prediction: 0,
                class_sums: vec![0; 10],
                sim_cycles: None,
                model_version: None,
                timing: None,
            })
            .collect())
    }
}

/// Lifecycle: with the worker deterministically wedged, a full bounded
/// queue returns `Overloaded` rather than blocking the submitter.
#[test]
fn full_queue_returns_overloaded_without_blocking() {
    let _serial = heavy_guard();
    let (gate_tx, gate_rx) = std::sync::mpsc::channel();
    let coord = Coordinator::start_with_capacity(
        move || GateBackend {
            geometry: Geometry::asic(),
            gate: gate_rx,
        },
        BatchConfig {
            max_batch: 1,
            max_wait: Duration::ZERO,
        },
        2,
    );
    let img = BoolImage::blank();
    let mut accepted = vec![coord.submit(img.clone())];
    // Wait for the worker to dequeue that request and wedge in classify,
    // then fill the 2-deep queue and observe non-blocking shedding.
    std::thread::sleep(Duration::from_millis(20));
    let mut shed = None;
    for _ in 0..8 {
        match coord.try_submit(img.clone()) {
            Ok(rx) => accepted.push(rx),
            Err(e) => {
                shed = Some(e);
                break;
            }
        }
    }
    let e = shed.expect("queue of capacity 2 accepted 8 extra requests");
    assert_eq!((e.shards, e.capacity), (1, 2));
    assert!(
        accepted.len() <= 4,
        "accepted {} requests into worker+capacity-2 queue",
        accepted.len()
    );
    // Release the wedge: one gate send per max_batch=1 batch.
    for _ in 0..accepted.len() {
        gate_tx.send(()).ok();
    }
    for rx in &accepted {
        rx.recv().unwrap().unwrap();
    }
    let snap = coord.shutdown();
    assert_eq!(snap.requests as usize, accepted.len());
    assert_eq!(snap.errors, 0);
}

/// Lifecycle: shutdown closes the queues and *drains* them — every
/// request accepted before shutdown gets its response.
#[test]
fn clean_shutdown_drains_queue_without_losing_responses() {
    let _serial = heavy_guard();
    let model = random_model(9, 4);
    let engine = Engine::new();
    let coord = pool(&model, 2, 256);
    let images = random_images(10, 100);
    let rxs: Vec<_> = images.iter().map(|i| coord.submit(i.clone())).collect();
    // Shut down immediately: most requests are still queued.
    let snap = coord.shutdown();
    assert_eq!(snap.requests, 100, "drain must serve every queued request");
    assert_eq!(snap.errors, 0);
    for (rx, img) in rxs.into_iter().zip(&images) {
        let out = rx.recv().expect("response lost in shutdown").unwrap();
        assert_eq!(out.prediction, engine.classify(&model, img).prediction);
    }
}

/// Supervision × hot-swap: a model swap that lands while the only shard
/// is down in its respawn window is still zero-drop and never serves a
/// stale `model_version`. The panicked in-flight request fails with the
/// typed [`ShardPanicked`]; everything queued behind the respawn is
/// served by the *new* model version, because the respawned worker
/// re-resolves its plans from the registry before touching the queue.
#[test]
fn hot_swap_during_worker_respawn_is_zero_drop_and_never_stale() {
    let _serial = heavy_guard();
    // The first evaluation unit in the process panics; nothing else fires.
    let _armed = fault::arm(FaultPlan::parse("seed=1,eval_panic=once1").unwrap());
    let registry = ModelRegistry::single("live", fixed_class_model(2));
    let coord = Coordinator::start_pool(
        Arc::clone(&registry),
        PoolConfig {
            shards: 1,
            queue_capacity: 1024,
            batch: BatchConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(50),
            },
            supervisor: SupervisorConfig {
                max_respawns: 5,
                respawn_window: Duration::from_secs(30),
                backoff_base: Duration::from_millis(200),
                backoff_cap: Duration::from_millis(200),
            },
            ..PoolConfig::default()
        },
    );
    let img = BoolImage::blank();

    // The injected panic fails the in-flight request with the typed error.
    let doomed = coord.submit_to(Some("live"), img.clone());
    let e = doomed.recv().expect("panicked request must still be answered").unwrap_err();
    let p = e.downcast_ref::<ShardPanicked>().expect("want ShardPanicked");
    assert_eq!(p.shard, 0);

    // The shard is now inside its 200 ms respawn backoff. Swap the model
    // and queue work behind the down worker — nothing may be dropped, and
    // every response must carry the post-swap weights and version.
    assert_ne!(coord.shard_health()[0], ShardHealth::Dead);
    let swapped = registry.swap("live", fixed_class_model(7)).unwrap();
    assert_eq!(swapped.version, 2);
    let rxs: Vec<_> = (0..50)
        .map(|_| coord.submit_to(Some("live"), img.clone()))
        .collect();
    for rx in rxs {
        let out = rx.recv().expect("request dropped across respawn").unwrap();
        assert_eq!(out.prediction, 7, "stale weights served after swap");
        assert_eq!(out.model_version, Some(2), "stale model_version after swap");
    }

    let snap = coord.metrics();
    assert_eq!(snap.shard_panics, 1);
    assert_eq!(snap.respawns, 1);
    assert_eq!(snap.shard_health, vec!["healthy"]);
    let snap = coord.shutdown();
    assert_eq!(snap.requests, 50);
    assert_eq!(snap.errors, 1);
    assert_eq!(snap.per_model["live"].requests, 50);
    assert_eq!(snap.per_model["live"].errors, 1);
}

/// Lifecycle: a wrong-model-id or wrong-geometry request fails *that
/// request only* — co-batched valid requests (including for other
/// models/geometries) are unaffected.
#[test]
fn bad_model_or_geometry_fails_request_not_batch() {
    let _serial = heavy_guard();
    let registry = ModelRegistry::new();
    registry.insert("mnist", random_model(11, 4)).unwrap();
    registry
        .insert("cifar", Model::blank(Params::for_geometry(Geometry::cifar10())))
        .unwrap();
    let coord = Coordinator::start_pool(
        Arc::new(registry),
        PoolConfig {
            shards: 1,
            queue_capacity: 256,
            batch: BatchConfig {
                max_batch: 16,
                max_wait: Duration::from_millis(5),
            },
            ..PoolConfig::default()
        },
    );
    let img28 = random_images(12, 1).remove(0);
    let img32 = BoolImage::blank_sized(32);
    // Interleave so the bad requests co-batch with good ones.
    let good_mnist: Vec<_> = (0..4)
        .map(|_| coord.submit_to(Some("mnist"), img28.clone()))
        .collect();
    let bad_geometry = coord.submit_to(Some("mnist"), img32.clone());
    let unknown_model = coord.submit_to(Some("ghost"), img28.clone());
    let good_cifar = coord.submit_to(Some("cifar"), img32.clone());
    let bad_cifar = coord.submit_to(Some("cifar"), img28.clone());

    for rx in good_mnist {
        rx.recv().unwrap().expect("valid mnist request poisoned");
    }
    let e = bad_geometry.recv().unwrap().unwrap_err();
    assert!(e.to_string().contains("32x32"), "{e}");
    let e = unknown_model.recv().unwrap().unwrap_err();
    assert!(e.to_string().contains("unknown model 'ghost'"), "{e}");
    good_cifar.recv().unwrap().expect("valid cifar request poisoned");
    let e = bad_cifar.recv().unwrap().unwrap_err();
    assert!(e.to_string().contains("28x28"), "{e}");

    let snap = coord.shutdown();
    assert_eq!(snap.errors, 3);
    assert_eq!(snap.requests, 5);
    assert_eq!(snap.per_model["mnist"].requests, 4);
    assert_eq!(snap.per_model["mnist"].errors, 1);
    assert_eq!(snap.per_model["cifar"].requests, 1);
    assert_eq!(snap.per_model["cifar"].errors, 1);
    assert_eq!(snap.per_model["ghost"].errors, 1);
}
