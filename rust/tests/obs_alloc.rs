//! Disarmed-tracing allocation discipline: with tracing disarmed (the
//! default), the per-request hook sequence — mint/adopt an id, open the
//! scope, record stages, close the scope — must allocate **nothing**.
//! This is the property that makes it safe to leave the hooks compiled
//! into the serving hot path; the `trace_overhead_pct` bench gate bounds
//! the time side of the same claim.
//!
//! This binary holds exactly one test so no concurrent test thread can
//! allocate during the measured window (the allocator count is global).

use convcotm::obs::{self, Stage, TraceId};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

struct CountingAlloc;

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn disarmed_request_scope_allocates_nothing() {
    assert!(!obs::armed(), "this binary must not arm tracing");

    // Warm up one full cycle: thread-local scope slot, the mint seed's
    // OnceLock, any lazy ring registration — one-time costs are fine.
    for _ in 0..8 {
        obs::begin_request(TraceId::mint());
        obs::record_stage(Stage::Parse, 1.0);
        obs::record_stage(Stage::Eval, 2.0);
        obs::record_stage(Stage::Serialize, 0.5);
        let done = obs::end_request(200);
        assert!(done.is_none(), "disarmed end_request must not complete traces");
    }

    let before = ALLOCATIONS.load(Ordering::Relaxed);
    for i in 0..100_000u32 {
        let id = if i % 2 == 0 {
            TraceId::mint()
        } else {
            TraceId::parse("adopted-client-id-1234").expect("valid id")
        };
        obs::begin_request(id);
        obs::record_stage(Stage::Parse, 1.0);
        obs::record_stage(Stage::QueueWait, 3.0);
        obs::record_stage(Stage::Eval, 2.0);
        obs::record_stage(Stage::Serialize, 0.5);
        obs::end_request(200);
    }
    let delta = ALLOCATIONS.load(Ordering::Relaxed) - before;
    assert_eq!(
        delta, 0,
        "disarmed tracing allocated {delta} time(s) across 100k request scopes"
    );
}
