//! Non-default-geometry integration: the §VI-C CIFAR-shaped 32×32
//! configuration trains on synthetic data and classifies end-to-end
//! through the serving stack (Coordinator + NativeBackend), with the ASIC
//! simulator mirroring the native engine bit-for-bit — the refactor's
//! acceptance path.

use convcotm::asic::ChipConfig;
use convcotm::coordinator::{
    AsicBackend, Backend, BatchConfig, Coordinator, MirrorBackend, NativeBackend,
};
use convcotm::data::{booleanize_split_for_geometry, Geometry, SynthFamily};
use convcotm::model_io;
use convcotm::tm::{Engine, Params, Trainer};

/// Train a 32×32 model on the synthetic digit substitute (center-padded
/// from its native 28×28), restricted to a binary sub-problem so the test
/// stays fast.
fn trained_cifar_shaped_fixture() -> (convcotm::tm::Model, Vec<(convcotm::data::BoolImage, u8)>) {
    let g = Geometry::cifar10();
    let dataset = SynthFamily::Digits.generate(300, 120, 17);
    let train: Vec<_> =
        booleanize_split_for_geometry(&dataset.train, dataset.booleanizer, g)
            .into_iter()
            .filter(|(_, l)| *l < 2)
            .collect();
    let test: Vec<_> = booleanize_split_for_geometry(&dataset.test, dataset.booleanizer, g)
        .into_iter()
        .filter(|(_, l)| *l < 2)
        .collect();
    let params = Params {
        clauses: 20,
        t: 20,
        s: 6.0,
        ..Params::for_geometry(g)
    };
    let mut trainer = Trainer::new(params, 17);
    for e in 0..6 {
        trainer.epoch(&train, e);
    }
    (trainer.export(), test)
}

#[test]
fn cifar_shaped_geometry_trains_and_serves_end_to_end() {
    let (model, test) = trained_cifar_shaped_fixture();
    assert_eq!(model.params.geometry, Geometry::cifar10());
    assert_eq!(model.params.literals, 288);

    // The model actually learned the (padded) problem at 32×32.
    let engine = Engine::new();
    let acc = engine.accuracy(&model, &test);
    assert!(acc > 0.85, "32×32 digit 0-vs-1 accuracy {acc}");

    // Save/load through the geometry-carrying container.
    let path = std::env::temp_dir().join("geometry_e2e_cifar.cctm");
    model_io::save_file(&model, &path).unwrap();
    let loaded = model_io::load_file_auto(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert!(loaded == model);

    // Serve through the coordinator over the native backend.
    let coord = Coordinator::start(
        Box::new(NativeBackend::new(loaded.clone())),
        BatchConfig::default(),
    );
    for (img, _) in test.iter().take(24) {
        let out = coord.classify(img.clone()).unwrap();
        assert_eq!(out.prediction, engine.classify(&loaded, img).prediction);
    }
    let snap = coord.shutdown();
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.requests, 24);
}

#[test]
fn cifar_shaped_mirror_native_vs_asic_sim() {
    let (model, test) = trained_cifar_shaped_fixture();
    let m1 = model.clone();
    let m2 = model;
    let coord = Coordinator::start_with(
        move || {
            MirrorBackend::new(
                Box::new(AsicBackend::new(&m1, ChipConfig::default())),
                Box::new(NativeBackend::new(m2.clone())),
            )
        },
        BatchConfig::default(),
    );
    let mut cycles = Vec::new();
    for (img, _) in test.iter().take(12) {
        let out = coord.classify(img.clone()).unwrap();
        cycles.push(out.sim_cycles.expect("asic-sim primary reports cycles"));
    }
    // Geometry-derived cycle budget: 529 patches + 11 fixed processing
    // cycles = 540; the first image also pays the 129-beat transfer.
    assert_eq!(cycles[0], 540 + 129);
    assert!(cycles[1..].iter().all(|&c| c == 540), "{cycles:?}");
    let snap = coord.shutdown();
    assert_eq!(snap.errors, 0, "ASIC sim must match native at 32×32");
    assert_eq!(snap.requests, 12);
}

#[test]
fn backend_rejects_wrong_geometry_requests() {
    let (model, _) = trained_cifar_shaped_fixture();
    let mut backend = NativeBackend::new(model);
    assert_eq!(backend.geometry(), Geometry::cifar10());
    // A default 28×28 request against the 32×32 model errors cleanly.
    let wrong = convcotm::data::BoolImage::blank();
    let err = backend.classify(&[&wrong]).unwrap_err();
    assert!(err.to_string().contains("expects 32x32"), "{err}");
}
