//! Cross-stack integration: trainer → model file → AXI load → ASIC
//! simulator ≡ native engine ≡ PJRT artifact, end to end — the repository
//! version of the paper's §V claim that silicon results match the SW model
//! exactly.

use convcotm::asic::{axi, Accelerator, ChipConfig};
use convcotm::coordinator::{BatchConfig, Coordinator, MirrorBackend, NativeBackend};
use convcotm::data::{booleanize_split, SynthFamily};
use convcotm::model_io;
use convcotm::tm::{Engine, Params, Trainer};

fn trained_fixture() -> (convcotm::tm::Model, Vec<(convcotm::data::BoolImage, u8)>) {
    let dataset = SynthFamily::Digits.generate(300, 80, 99);
    let train = booleanize_split(&dataset.train, dataset.booleanizer);
    let test = booleanize_split(&dataset.test, dataset.booleanizer);
    let mut trainer = Trainer::new(Params::asic(), 99);
    for e in 0..3 {
        trainer.epoch(&train, e);
    }
    (trainer.export(), test)
}

#[test]
fn train_save_load_axi_classify_roundtrip() {
    let (model, test) = trained_fixture();

    // Save → load through the on-disk container.
    let path = std::env::temp_dir().join("cross_stack_model.cctm");
    model_io::save_file(&model, &path).unwrap();
    let loaded = model_io::load_file(Params::asic(), &path).unwrap();
    std::fs::remove_file(&path).ok();

    // Push through the AXI load-model framing into the accelerator.
    let wire = model_io::to_wire(&loaded);
    let beats = axi::frame_model(&wire, loaded.params.model_wire_bytes());
    assert_eq!(beats.len(), 5_632);
    let payload: Vec<u8> = beats.iter().map(|b| b.data).collect();
    let mut acc = Accelerator::new(Params::asic(), ChipConfig::default());
    acc.load_model_wire(&payload).unwrap();

    // Classify through the AXI image framing too.
    let engine = Engine::new();
    let mut deframer = axi::ImageDeframer::new();
    for (img, label) in test.iter().take(20) {
        // Frame, deframe (the accelerator's receive path), classify.
        let mut received = None;
        for beat in axi::frame_image(img, Some(*label)) {
            if let Some(r) = deframer.push(beat).unwrap() {
                received = Some(r);
            }
        }
        let (rx_img, rx_label) = received.unwrap();
        assert_eq!(&rx_img, img);
        assert_eq!(rx_label, Some(*label));
        let sim = acc.classify(&rx_img, rx_label, true).unwrap();
        let sw = engine.classify(&model, img);
        assert_eq!(sim.prediction, sw.prediction);
        assert_eq!(sim.class_sums, sw.class_sums);
        // Result byte framing round-trips.
        let byte = axi::encode_result(sim.prediction, sim.label_echo);
        assert_eq!(axi::decode_result(byte), (sim.prediction, Some(*label)));
    }
}

#[cfg(feature = "pjrt")]
#[test]
fn trained_model_matches_pjrt_artifact() {
    use convcotm::runtime::{ModelInputs, Runtime};
    use std::path::PathBuf;
    let artifact_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !artifact_dir.join("convcotm_b1.hlo.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let (model, test) = trained_fixture();
    let mut rt = Runtime::new(&artifact_dir).unwrap();
    let graph = rt.load("convcotm_b1", 1).unwrap();
    let inputs = ModelInputs::from_model(&model);
    let engine = Engine::new();
    for (img, _) in test.iter().take(12) {
        let out = &graph.run(&[img], &inputs).unwrap()[0];
        let sw = engine.classify(&model, img);
        assert_eq!(out.prediction, sw.prediction);
        let sums: Vec<i32> = out.class_sums.iter().map(|&x| x as i32).collect();
        assert_eq!(sums, sw.class_sums);
    }
}

#[test]
fn coordinator_mirror_over_trained_model() {
    let (model, test) = trained_fixture();
    let m1 = model.clone();
    let m2 = model;
    let coord = Coordinator::start_with(
        move || {
            MirrorBackend::new(
                Box::new(NativeBackend::new(m1.clone())),
                Box::new(convcotm::coordinator::AsicBackend::new(
                    &m2,
                    ChipConfig::default(),
                )),
            )
        },
        BatchConfig::default(),
    );
    for (img, _) in test.iter().take(30) {
        coord.classify(img.clone()).unwrap();
    }
    let snap = coord.shutdown();
    assert_eq!(snap.errors, 0, "mirror must not diverge");
    assert_eq!(snap.requests, 30);
}

#[test]
fn csrf_and_gating_do_not_change_results() {
    let (model, test) = trained_fixture();
    let configs = [
        ChipConfig { csrf: true, clock_gating: true },
        ChipConfig { csrf: false, clock_gating: true },
        ChipConfig { csrf: true, clock_gating: false },
        ChipConfig { csrf: false, clock_gating: false },
    ];
    let engine = Engine::new();
    for cfg in configs {
        let mut acc = Accelerator::new(Params::asic(), cfg);
        acc.load_model(&model);
        for (img, _) in test.iter().take(10) {
            let sim = acc.classify(img, None, true).unwrap();
            let sw = engine.classify(&model, img);
            assert_eq!(sim.prediction, sw.prediction, "{cfg:?}");
            assert_eq!(sim.class_sums, sw.class_sums, "{cfg:?}");
        }
    }
}

#[test]
fn literal_budget_pipeline_end_to_end() {
    // §VI-A variant: budget-constrained training → budgeted encoding →
    // bit-exact agreement with the dense model on the test set.
    let dataset = SynthFamily::Digits.generate(600, 60, 5);
    let train = booleanize_split(&dataset.train, dataset.booleanizer);
    let test = booleanize_split(&dataset.test, dataset.booleanizer);
    // Lower specificity (s=4) suits budget-constrained clauses: shorter
    // patterns form before the include cap binds ([42] trains similarly).
    let params = Params {
        literal_budget: Some(10),
        s: 4.0,
        ..Params::asic()
    };
    let mut trainer = Trainer::new(params, 5);
    for e in 0..6 {
        trainer.epoch(&train, e);
    }
    let model = trainer.export();
    assert!(model.max_clause_size() <= 10);
    let budgeted = convcotm::tm::budget::BudgetedModel::from_model(&model, 10).unwrap();
    // Budgeted TA storage is 90 bits/clause as §VI-A computes.
    assert_eq!(budgeted.ta_action_bits(), 128 * 90);
    let engine = Engine::new();
    for (img, _) in test.iter().take(15) {
        let sw = engine.classify(&model, img);
        // Evaluate the budgeted clauses directly on each patch and OR.
        let patches =
            convcotm::data::patches::all_patch_literals(model.params.geometry, img);
        for (j, clause) in budgeted.clauses.iter().enumerate() {
            let fired = patches.iter().any(|lits| clause.fires(lits));
            assert_eq!(fired, sw.clauses.get(j), "clause {j}");
        }
    }
    // The budgeted model should still classify usefully.
    let acc = engine.accuracy(&model, &test);
    assert!(acc > 0.5, "budgeted accuracy {acc}");
}
