//! Route-tier integration over real sockets: one router process fronting
//! live `serve` replicas. Covers rendezvous placement (every request for
//! a model lands on its one owner), tier-wide inventory and metrics
//! aggregation, admin fan-out error relay, and the acceptance property:
//! killing a replica mid-load loses zero requests and emits zero
//! non-envelope errors.

use convcotm::coordinator::{BatchConfig, Coordinator, ModelRegistry, PoolConfig};
use convcotm::data::BoolImage;
use convcotm::server::http::write_request;
use convcotm::server::proto::{classify_request_body, parse_error_body};
use convcotm::server::router::{rank_replicas, spawn_health_checker, RouterConfig, RouterState};
use convcotm::server::{
    ClientResponse, HttpConn, HttpServer, Limits, ServerConfig, ServerState,
};
use convcotm::tm::{Model, Params};
use convcotm::util::Json;
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Socket tests are timing-sensitive; keep them serial within this binary.
static HEAVY: Mutex<()> = Mutex::new(());

fn heavy_guard() -> std::sync::MutexGuard<'static, ()> {
    HEAVY.lock().unwrap_or_else(|e| e.into_inner())
}

fn fixed_class_model(class: usize) -> Model {
    let p = Params::asic();
    let mut m = Model::blank(p.clone());
    m.set_include(0, p.geometry.num_features(), true);
    m.set_weight(class, 0, 5);
    m
}

/// One live `serve` replica over a single-model registry.
struct TestReplica {
    server: HttpServer,
    state: Arc<ServerState>,
    coord: Arc<Coordinator>,
    addr: String,
}

fn start_replica(registry: Arc<ModelRegistry>) -> TestReplica {
    let coord = Arc::new(Coordinator::start_pool(
        registry,
        PoolConfig {
            shards: 1,
            queue_capacity: 256,
            batch: BatchConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(50),
            },
            ..PoolConfig::default()
        },
    ));
    let state = ServerState::new(Arc::clone(&coord));
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        http_workers: 2,
        ..ServerConfig::default()
    };
    let server = HttpServer::start(&cfg, Arc::clone(&state)).expect("bind replica");
    let addr = server.local_addr().to_string();
    TestReplica {
        server,
        state,
        coord,
        addr,
    }
}

fn kill_replica(r: TestReplica) {
    r.server.request_shutdown();
    r.server.join();
    drop(r.state);
    if let Ok(coord) = Arc::try_unwrap(r.coord) {
        coord.shutdown();
    }
}

/// One router in front of `replicas`, with its health checker running.
struct TestRouter {
    server: HttpServer,
    state: Arc<RouterState>,
    health: JoinHandle<()>,
}

fn start_router(replicas: Vec<String>, health_interval: Duration) -> TestRouter {
    let state = RouterState::new(RouterConfig {
        replicas,
        health_interval,
        ..RouterConfig::default()
    })
    .expect("router state");
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        http_workers: 2,
        ..ServerConfig::default()
    };
    let server = HttpServer::start(&cfg, Arc::clone(&state)).expect("bind router");
    let health = spawn_health_checker(Arc::clone(&state));
    TestRouter {
        server,
        state,
        health,
    }
}

fn kill_router(r: TestRouter) {
    r.server.request_shutdown();
    r.server.join();
    r.health.join().expect("health checker panicked");
}

fn connect(addr: &str) -> HttpConn<TcpStream> {
    let stream = TcpStream::connect(addr).expect("connect to loopback server");
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.set_nodelay(true).unwrap();
    HttpConn::new(stream)
}

fn roundtrip(
    conn: &mut HttpConn<TcpStream>,
    method: &str,
    path: &str,
    body: &[u8],
) -> ClientResponse {
    write_request(conn.get_mut(), method, path, body, true).expect("write request");
    conn.read_response(&Limits::default())
        .expect("read response")
        .expect("server closed connection before responding")
}

fn body_json(resp: &ClientResponse) -> Json {
    Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap()
}

/// Rendezvous placement end to end: every classify for one model lands on
/// the same single owner; the other replica never sees a forward.
#[test]
fn classify_requests_route_consistently_to_one_owner() {
    let _serial = heavy_guard();
    let registry = || ModelRegistry::single("live", fixed_class_model(3));
    let (a, b) = (start_replica(registry()), start_replica(registry()));
    let router = start_router(vec![a.addr.clone(), b.addr.clone()], Duration::from_millis(50));

    let img = BoolImage::blank();
    let body = classify_request_body(Some("live"), &[&img]);
    let mut conn = connect(&router.server.local_addr().to_string());
    for _ in 0..20 {
        let resp = roundtrip(&mut conn, "POST", "/v1/classify", &body);
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let v = body_json(&resp);
        let class = v.get("results").and_then(Json::as_arr).unwrap()[0]
            .get("class")
            .and_then(Json::as_f64);
        assert_eq!(class, Some(3.0));
    }

    let forwards: Vec<u64> = router
        .state
        .replicas
        .iter()
        .map(|r| r.forwarded.load(Ordering::Relaxed))
        .collect();
    assert_eq!(forwards.iter().sum::<u64>(), 20);
    assert!(
        forwards.contains(&20) && forwards.contains(&0),
        "placement split across replicas: {forwards:?}"
    );

    let resp = roundtrip(&mut conn, "GET", "/healthz", b"");
    assert_eq!(resp.status, 200);
    let v = body_json(&resp);
    assert_eq!(v.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(v.get("role").and_then(Json::as_str), Some("router"));

    kill_router(router);
    kill_replica(a);
    kill_replica(b);
}

/// Tier-wide read paths: `/v1/models` unions disjoint inventories,
/// `/v1/metrics` sums replica counters (raw per-replica snapshots are
/// demoted to a `"debug"` breakdown — fleet percentiles come from the
/// merged histograms), and fan-out admin failures relay the worst
/// replica's stable code.
#[test]
fn inventory_metrics_and_admin_errors_aggregate_across_the_tier() {
    let _serial = heavy_guard();
    let a = start_replica(ModelRegistry::single("alpha", fixed_class_model(1)));
    let b = start_replica(ModelRegistry::single("beta", fixed_class_model(2)));
    let router = start_router(vec![a.addr.clone(), b.addr.clone()], Duration::from_millis(50));
    let mut conn = connect(&router.server.local_addr().to_string());

    // Inventory union of two disjoint single-model replicas.
    let resp = roundtrip(&mut conn, "GET", "/v1/models", b"");
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    let v = body_json(&resp);
    let mut names: Vec<&str> = v
        .get("models")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter_map(|m| m.get("name").and_then(Json::as_str))
        .collect();
    names.sort_unstable();
    assert_eq!(names, ["alpha", "beta"]);
    let raw = v.get("replicas").unwrap();
    assert!(raw.get(&a.addr).is_some() && raw.get(&b.addr).is_some());

    // One classify directly at each replica, then the router's /metrics
    // must show the summed count plus the raw breakdown.
    for (replica, model) in [(&a, "alpha"), (&b, "beta")] {
        let img = BoolImage::blank();
        let body = classify_request_body(Some(model), &[&img]);
        let mut direct = connect(&replica.addr);
        let resp = roundtrip(&mut direct, "POST", "/v1/classify", &body);
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    }
    let resp = roundtrip(&mut conn, "GET", "/v1/metrics", b"");
    assert_eq!(resp.status, 200);
    let v = body_json(&resp);
    assert_eq!(v.get("requests").and_then(Json::as_f64), Some(2.0));
    for key in ["debug", "http", "router"] {
        assert!(v.get(key).is_some(), "router /v1/metrics missing '{key}'");
    }
    // The deprecated alias spelling still answers the same body.
    let resp = roundtrip(&mut conn, "GET", "/metrics", b"");
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("deprecation"), Some("true"));

    // Fan-out admin failure: both replicas reject the empty manifest, the
    // router relays the worst status and its stable code.
    let resp = roundtrip(&mut conn, "POST", "/v1/admin/models", b"");
    assert_eq!(resp.status, 400);
    let e = parse_error_body(&resp.body).expect("uniform envelope from the router");
    assert_eq!(e.code, "bad_manifest");
    assert!(e.message.contains("2/2 replica(s) failed"), "{}", e.message);

    // Unknown path: the router speaks the same envelope as a replica.
    let resp = roundtrip(&mut conn, "GET", "/nope", b"");
    assert_eq!(resp.status, 404);
    assert_eq!(parse_error_body(&resp.body).unwrap().code, "not_found");

    kill_router(router);
    kill_replica(a);
    kill_replica(b);
}

/// The acceptance property: killing the owning replica mid-load drops
/// zero requests — every response is either `200` or a well-formed
/// envelope, and traffic re-homes to the survivor.
#[test]
fn replica_death_fails_over_with_zero_drops() {
    let _serial = heavy_guard();
    let registry = || ModelRegistry::single("live", fixed_class_model(3));
    let (a, b) = (start_replica(registry()), start_replica(registry()));
    let router = start_router(vec![a.addr.clone(), b.addr.clone()], Duration::from_millis(25));
    let router_addr = router.server.local_addr().to_string();

    // Which replica owns "live" is a pure function of the addresses.
    let addrs = [a.addr.as_str(), b.addr.as_str()];
    let owner_is_a = rank_replicas("live", &addrs)[0] == 0;

    const TOTAL: usize = 300;
    let progress = Arc::new(AtomicUsize::new(0));
    let loader = {
        let progress = Arc::clone(&progress);
        let addr = router_addr.clone();
        std::thread::spawn(move || -> Vec<ClientResponse> {
            let img = BoolImage::blank();
            let body = classify_request_body(Some("live"), &[&img]);
            let mut conn = connect(&addr);
            let mut out = Vec::with_capacity(TOTAL);
            let mut reconnect_budget = 16usize;
            while out.len() < TOTAL {
                let wrote = write_request(conn.get_mut(), "POST", "/v1/classify", &body, true);
                let resp = match wrote {
                    Ok(()) => conn.read_response(&Limits::default()).ok().flatten(),
                    Err(_) => None,
                };
                match resp {
                    Some(resp) => {
                        out.push(resp);
                        progress.fetch_add(1, Ordering::Relaxed);
                    }
                    None => {
                        // The router itself never drops a request silently;
                        // a closed connection is re-dialed, bounded.
                        reconnect_budget -= 1;
                        assert!(reconnect_budget > 0, "router keeps closing the connection");
                        conn = connect(&addr);
                    }
                }
            }
            out
        })
    };

    // Let traffic establish on the owner, then kill it mid-load.
    let t0 = Instant::now();
    while progress.load(Ordering::Relaxed) < 100 {
        assert!(t0.elapsed() < Duration::from_secs(30), "loader stalled");
        std::thread::sleep(Duration::from_millis(1));
    }
    let (owner, survivor) = if owner_is_a { (a, b) } else { (b, a) };
    kill_replica(owner);

    let responses = loader.join().expect("loader thread panicked");
    assert_eq!(responses.len(), TOTAL);
    let mut ok = 0usize;
    for (i, resp) in responses.iter().enumerate() {
        if resp.status == 200 {
            ok += 1;
        } else {
            // Zero non-enveloped failures, even in the kill window.
            let e = parse_error_body(&resp.body).unwrap_or_else(|| {
                panic!(
                    "response {i}: HTTP {} without envelope: {}",
                    resp.status,
                    String::from_utf8_lossy(&resp.body)
                )
            });
            assert!(
                ["replica_unavailable", "overloaded", "shard_panicked"]
                    .contains(&e.code.as_str()),
                "response {i}: unexpected failover code {}",
                e.code
            );
        }
    }
    assert!(ok >= 250, "only {ok}/{TOTAL} requests succeeded across the failover");
    let tail_ok = responses[TOTAL - 50..].iter().all(|r| r.status == 200);
    assert!(tail_ok, "traffic did not settle on the survivor after failover");

    // The router noticed: health reports a degraded tier.
    let mut conn = connect(&router_addr);
    let resp = roundtrip(&mut conn, "GET", "/healthz", b"");
    assert_eq!(resp.status, 200);
    assert_eq!(body_json(&resp).get("status").and_then(Json::as_str), Some("degraded"));

    kill_router(router);
    kill_replica(survivor);
}
