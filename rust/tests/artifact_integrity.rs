//! End-to-end artifact integrity (DESIGN.md §12): no corrupt v4 frame —
//! truncated anywhere or with any bit flipped — may load as anything but
//! a typed [`ModelIoError`], and never a panic. Also covers the armed
//! write-path faults: a torn write is caught by the CRC footer on load,
//! and an injected I/O error fails the save while leaving the previous
//! artifact intact (the `write_atomic` contract).

use convcotm::model_io::{self, ModelIoError};
use convcotm::tm::{Model, Params, TrainCheckpoint};
use convcotm::util::fault::{self, FaultPlan};
use std::path::PathBuf;

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("convcotm_artifact_integrity");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn sample_model() -> Model {
    let p = Params::asic();
    let mut m = Model::blank(p.clone());
    for j in 0..p.clauses {
        m.set_include(j, j % p.literals, true);
        m.set_weight(j % p.classes, j, (j % 19) as i8 - 9);
    }
    m
}

fn sample_checkpoint() -> TrainCheckpoint {
    let p = Params::asic();
    TrainCheckpoint {
        dataset: "integrity:1:1".to_string(),
        seed: 0xC0FFEE,
        samples_seen: 12_345,
        epochs_done: 3,
        boost_true_positive: true,
        ta_states: (0..p.clauses * p.literals).map(|i| (i % 200) as u8).collect(),
        wide_weights: (0..p.clauses * p.classes).map(|i| i as i32 - 640).collect(),
        params: p,
    }
}

/// Cut points: every frame-header boundary, a sweep through the body, and
/// the bytes around the CRC footer.
fn truncation_points(len: usize) -> Vec<usize> {
    let mut pts: Vec<usize> = (0..12.min(len)).collect();
    pts.extend((0..len).step_by(509));
    pts.extend((1..=5).filter_map(|d| len.checked_sub(d)));
    pts.retain(|&p| p < len);
    pts.sort_unstable();
    pts.dedup();
    pts
}

/// Flip positions: the whole frame header bit-by-bit candidates, a sweep
/// through the body, and the CRC footer itself.
fn flip_points(len: usize) -> Vec<usize> {
    let mut pts: Vec<usize> = (0..8.min(len)).collect();
    pts.extend((0..len).step_by(97));
    pts.extend((1..=4).filter_map(|d| len.checked_sub(d)));
    pts.retain(|&p| p < len);
    pts.sort_unstable();
    pts.dedup();
    pts
}

/// The corruption matrix: every truncation and every single-bit flip of a
/// v4 model or checkpoint frame is rejected with a typed error (a panic
/// anywhere would fail the test thread). The CRC footer must be doing
/// real work: most body corruptions surface as `ChecksumMismatch`.
#[test]
fn corruption_matrix_rejects_every_damaged_v4_frame_typed() {
    // An empty plan injects nothing but holds the process-wide arm lock,
    // so the armed tests in this binary cannot steal this test's writes.
    let _quiesced = fault::arm(FaultPlan::parse("seed=0").unwrap());
    let model_path = scratch("matrix_model.cctm");
    let ckpt_path = scratch("matrix_ckpt.ckpt");
    model_io::save_file(&sample_model(), &model_path).unwrap();
    model_io::save_checkpoint(&sample_checkpoint(), &ckpt_path).unwrap();

    let cases: [(&PathBuf, fn(&PathBuf) -> Option<ModelIoError>); 2] = [
        (&model_path, |p| model_io::load_file_auto(p).err()),
        (&ckpt_path, |p| model_io::load_checkpoint(p).err()),
    ];
    let mut crc_catches = 0usize;
    for (path, load) in cases {
        let intact = std::fs::read(path).unwrap();
        assert!(load(path).is_none(), "intact artifact must load");
        let damaged = scratch("matrix_damaged.bin");
        for cut in truncation_points(intact.len()) {
            std::fs::write(&damaged, &intact[..cut]).unwrap();
            let e = load(&damaged)
                .unwrap_or_else(|| panic!("{}: truncation to {cut} bytes loaded", path.display()));
            if matches!(e, ModelIoError::ChecksumMismatch { .. }) {
                crc_catches += 1;
            }
        }
        for pos in flip_points(intact.len()) {
            let mut bytes = intact.clone();
            bytes[pos] ^= 1 << (pos % 8);
            std::fs::write(&damaged, &bytes).unwrap();
            let e = load(&damaged)
                .unwrap_or_else(|| panic!("{}: bit flip at {pos} loaded", path.display()));
            if matches!(e, ModelIoError::ChecksumMismatch { .. }) {
                crc_catches += 1;
            }
        }
    }
    assert!(
        crc_catches > 50,
        "only {crc_catches} corruptions were caught by the CRC footer — is it being verified?"
    );

    // Cross-kind confusion is typed too, not a parse accident.
    assert!(matches!(
        model_io::load_checkpoint(&model_path),
        Err(ModelIoError::ModelNotCheckpoint(4))
    ));
    assert!(matches!(
        model_io::load_file_auto(&ckpt_path),
        Err(ModelIoError::CheckpointNotModel)
    ));
}

/// Legacy footer-less frames keep loading: a hand-built v2 model and a v3
/// checkpoint (the v4 body re-wrapped under the old version) round-trip
/// through the v4-era loaders.
#[test]
fn legacy_v2_model_and_v3_checkpoint_still_load() {
    // Empty plan: injection stays off, but the arm lock serializes us
    // against the armed tests in this binary (we call the save paths).
    let _quiesced = fault::arm(FaultPlan::parse("seed=0").unwrap());
    // v2 model: magic · version=2 · 6 dims · wire payload, no footer.
    let model = sample_model();
    let p = &model.params;
    let mut v2 = Vec::new();
    v2.extend_from_slice(b"CCTM");
    v2.extend_from_slice(&2u16.to_le_bytes());
    for dim in [
        p.clauses as u32,
        p.classes as u32,
        p.literals as u32,
        p.geometry.img_side as u32,
        p.geometry.window as u32,
        p.geometry.stride as u32,
    ] {
        v2.extend_from_slice(&dim.to_le_bytes());
    }
    v2.extend_from_slice(&model_io::to_wire(&model));
    let v2_path = scratch("legacy_model.cctm");
    std::fs::write(&v2_path, &v2).unwrap();
    let loaded = model_io::load_file_auto(&v2_path).unwrap();
    assert_eq!(model_io::to_wire(&loaded), model_io::to_wire(&model));

    // v3 checkpoint: the v4 frame's body under the legacy version header
    // (strip magic+version+kind and the 4-byte footer).
    let ck = sample_checkpoint();
    let v4_path = scratch("legacy_src.ckpt");
    model_io::save_checkpoint(&ck, &v4_path).unwrap();
    let v4 = std::fs::read(&v4_path).unwrap();
    let mut v3 = Vec::new();
    v3.extend_from_slice(b"CCTM");
    v3.extend_from_slice(&3u16.to_le_bytes());
    v3.extend_from_slice(&v4[7..v4.len() - 4]);
    let v3_path = scratch("legacy_ckpt.ckpt");
    std::fs::write(&v3_path, &v3).unwrap();
    let loaded = model_io::load_checkpoint(&v3_path).unwrap();
    assert_eq!(loaded.samples_seen, ck.samples_seen);
    assert_eq!(loaded.epochs_done, ck.epochs_done);
    assert_eq!(loaded.seed, ck.seed);
    assert_eq!(loaded.dataset, ck.dataset);
    assert_eq!(loaded.ta_states, ck.ta_states);
    assert_eq!(loaded.wide_weights, ck.wide_weights);
}

/// Armed torn-write fault: the save "succeeds" but the renamed file is
/// short — exactly the crash the CRC footer exists for. The next load
/// reports typed corruption; a clean re-save repairs the artifact.
#[test]
fn injected_torn_write_is_caught_by_crc_on_load() {
    let path = scratch("torn_write.ckpt");
    let ck = sample_checkpoint();
    {
        let _armed = fault::arm(FaultPlan::parse("seed=1,ckpt_write_truncate=once1:9").unwrap());
        model_io::save_checkpoint(&ck, &path).unwrap();
    }
    match model_io::load_checkpoint(&path).err() {
        Some(ModelIoError::ChecksumMismatch { .. }) | Some(ModelIoError::Truncated { .. }) => {}
        other => panic!("torn write must surface as typed corruption, got {other:?}"),
    }
    model_io::save_checkpoint(&ck, &path).unwrap();
    assert_eq!(model_io::load_checkpoint(&path).unwrap().samples_seen, ck.samples_seen);
}

/// Armed I/O-error fault: the save fails with a typed error and the
/// previous artifact at the same path is untouched — `write_atomic` never
/// exposes a half-written target.
#[test]
fn injected_io_error_fails_save_and_preserves_previous_artifact() {
    let path = scratch("io_error.cctm");
    let before = sample_model();
    model_io::save_file(&before, &path).unwrap();
    let mut after = sample_model();
    after.set_weight(0, 0, 7);
    {
        let _armed = fault::arm(FaultPlan::parse("seed=1,io_error=once1").unwrap());
        match model_io::save_file(&after, &path) {
            Err(ModelIoError::Io(e)) => {
                assert!(e.to_string().contains("fault injected"), "{e}");
            }
            other => panic!("armed io_error must fail the save, got {other:?}"),
        }
    }
    let survived = model_io::load_file_auto(&path).unwrap();
    assert_eq!(model_io::to_wire(&survived), model_io::to_wire(&before));
    model_io::save_file(&after, &path).unwrap();
    assert_eq!(
        model_io::to_wire(&model_io::load_file_auto(&path).unwrap()),
        model_io::to_wire(&after)
    );
}
