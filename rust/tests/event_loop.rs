//! Event-loop acceptance (DESIGN.md §13): the readiness loop holds
//! thousands of idle keep-alive connections with thread count O(workers),
//! and stays responsive — to fresh connections and to the parked ones —
//! the whole time. This is the property the thread-per-connection design
//! could not have: before the redesign, 2,000 parked sockets meant 2,000
//! blocked threads.

use convcotm::coordinator::{BatchConfig, Coordinator, ModelRegistry, PoolConfig};
use convcotm::data::BoolImage;
use convcotm::server::http::write_request;
use convcotm::server::{ClientResponse, HttpConn, HttpServer, Limits, ServerConfig, ServerState};
use convcotm::tm::{Model, Params};
use convcotm::util::poll::raise_nofile_limit;
use convcotm::util::Json;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

/// Deterministically predicts `class` on a blank image.
fn fixed_class_model(class: usize) -> Model {
    let p = Params::asic();
    let mut m = Model::blank(p.clone());
    m.set_include(0, p.geometry.num_features(), true);
    m.set_weight(class, 0, 5);
    m
}

fn connect(addr: SocketAddr) -> HttpConn<TcpStream> {
    let stream = TcpStream::connect(addr).expect("connect to loopback server");
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.set_nodelay(true).unwrap();
    HttpConn::new(stream)
}

fn roundtrip(
    conn: &mut HttpConn<TcpStream>,
    method: &str,
    path: &str,
    body: &[u8],
) -> ClientResponse {
    write_request(conn.get_mut(), method, path, body, true).expect("write request");
    conn.read_response(&Limits::default())
        .expect("read response")
        .expect("server closed connection before responding")
}

/// This process's live thread count, from `/proc/self/status`.
#[cfg(target_os = "linux")]
fn thread_count() -> usize {
    let status = std::fs::read_to_string("/proc/self/status").expect("read /proc/self/status");
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
        .expect("Threads: line in /proc/self/status")
}

/// Acceptance: ≥ 2,000 concurrent idle keep-alive connections on
/// `--http-workers 4`, thread count stays O(workers), and both a fresh
/// connection and a parked one still get served while the others sit.
#[test]
fn two_thousand_idle_keep_alive_connections_cost_a_slab_slot_not_a_thread() {
    // Every parked connection is two fds in this test process (client and
    // server end share it); the server raises its own budget on start but
    // the client side needs headroom too.
    let limit = raise_nofile_limit(16_384);
    let target = 2_000usize;
    let conns_wanted = if limit >= 5_000 {
        target
    } else {
        // Constrained sandbox: exercise the same property at the scale the
        // fd budget allows rather than failing on an environment limit.
        (limit.saturating_sub(512) / 2) as usize
    };
    assert!(conns_wanted >= 256, "nofile limit {limit} leaves no room to test the event loop");

    let coord = start_pool();
    let state = ServerState::new(Arc::clone(&coord));
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        http_workers: 4,
        // Idle connections must out-sit the whole test.
        idle_timeout: Duration::from_secs(120),
        ..ServerConfig::default()
    };
    let server = HttpServer::start(&cfg, Arc::clone(&state)).expect("bind loopback");
    let addr = server.local_addr();

    #[cfg(target_os = "linux")]
    let threads_before = thread_count();

    // Park a horde of connected-but-silent keep-alive clients.
    let mut parked: Vec<TcpStream> = Vec::with_capacity(conns_wanted);
    while parked.len() < conns_wanted {
        match TcpStream::connect(addr) {
            Ok(s) => parked.push(s),
            // Transient accept-queue pressure: give the loop a beat.
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }

    // Thread count is O(workers + shards), not O(connections): the horde
    // must not have spawned anything.
    #[cfg(target_os = "linux")]
    {
        let threads = thread_count();
        assert!(
            threads < 64,
            "{threads} threads while holding {conns_wanted} connections — \
             idle connections are costing threads (started at {threads_before})"
        );
        assert!(
            threads <= threads_before,
            "the parked horde grew the thread count {threads_before} → {threads}"
        );
    }

    // The server still answers a *fresh* connection while the horde sits.
    let img = BoolImage::blank();
    let body = convcotm::server::proto::classify_request_body(Some("m"), &[&img]);
    let mut fresh = connect(addr);
    let resp = roundtrip(&mut fresh, "POST", "/v1/classify", &body);
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    let v = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    let class = v.get("results").and_then(Json::as_arr).unwrap()[0]
        .get("class")
        .and_then(Json::as_f64);
    assert_eq!(class, Some(3.0));

    // And a *parked* connection was held alive, not silently dropped: its
    // first request after the long sit still round-trips.
    let parked_one = parked.pop().expect("at least one parked connection");
    parked_one.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut conn = HttpConn::new(parked_one);
    let resp = roundtrip(&mut conn, "GET", "/healthz", b"");
    assert_eq!(resp.status, 200);

    // Accounting: every connection in the horde was accepted, none shed.
    let accepted = state.stats.connections.load(Ordering::Relaxed);
    assert!(
        accepted >= (conns_wanted + 1) as u64,
        "only {accepted} connections accepted of {conns_wanted} parked"
    );
    assert_eq!(state.stats.rejected_conns.load(Ordering::Relaxed), 0);

    // Drain with the horde still parked: the drain closes idle
    // connections immediately instead of waiting out their timeouts.
    let t0 = std::time::Instant::now();
    server.request_shutdown();
    server.join();
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "drain hung {:?} with idle connections parked",
        t0.elapsed()
    );
    drop(parked);
    drop(state);
    if let Ok(coord) = Arc::try_unwrap(coord) {
        coord.shutdown();
    }
}

fn start_pool() -> Arc<Coordinator> {
    Arc::new(Coordinator::start_pool(
        ModelRegistry::single("m", fixed_class_model(3)),
        PoolConfig {
            shards: 1,
            queue_capacity: 1024,
            batch: BatchConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(50),
            },
            ..PoolConfig::default()
        },
    ))
}
