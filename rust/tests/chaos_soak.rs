//! Deterministic chaos soaks for the supervised shard pool (DESIGN.md
//! §12): under injected evaluation panics, worker respawns and concurrent
//! model hot-swaps, every accepted request gets exactly one typed
//! response — nothing is lost, nothing is mis-versioned. Each test arms a
//! process-wide [`FaultPlan`]; the [`fault::arm`] guard serializes them.

use convcotm::coordinator::{
    BatchConfig, Coordinator, DeadlineExceeded, ModelRegistry, PoolConfig, ShardHealth,
    ShardPanicked, SupervisorConfig,
};
use convcotm::data::BoolImage;
use convcotm::tm::{Model, Params};
use convcotm::util::fault::{self, FaultPlan, Site};
use std::sync::Arc;
use std::time::Duration;

/// A model that deterministically predicts `class` on a blank image: one
/// clause over a negated content literal (true on every patch of a blank
/// image) voting +5 for `class`.
fn fixed_class_model(class: usize) -> Model {
    let p = Params::asic();
    let mut m = Model::blank(p.clone());
    m.set_include(0, p.geometry.num_features(), true);
    m.set_weight(class, 0, 5);
    m
}

fn fast_supervisor() -> SupervisorConfig {
    SupervisorConfig {
        max_respawns: 100_000,
        respawn_window: Duration::from_secs(30),
        backoff_base: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(5),
    }
}

/// The determinism contract: the fire/no-fire schedule of a probabilistic
/// site is a pure function of (seed, site, hit index). Same seed → same
/// schedule, different seed → different schedule; no arming involved.
#[test]
fn same_seed_gives_the_same_fault_schedule() {
    let spec = "seed=42,eval_panic=p0.05,eval_delay=p0.2:3";
    let a = FaultPlan::parse(spec).unwrap();
    let b = FaultPlan::parse(spec).unwrap();
    let schedule = |plan: &FaultPlan, site: Site| -> Vec<bool> {
        (0..10_000).map(|hit| plan.would_fire(site, hit)).collect()
    };
    for site in [Site::EvalPanic, Site::EvalDelay] {
        assert_eq!(schedule(&a, site), schedule(&b, site));
    }
    let fired = schedule(&a, Site::EvalPanic).iter().filter(|&&f| f).count();
    assert!(
        (200..=800).contains(&fired),
        "p0.05 over 10k hits fired {fired} times — stream is not Bernoulli(0.05)"
    );
    let c = FaultPlan::parse("seed=43,eval_panic=p0.05").unwrap();
    assert_ne!(
        schedule(&a, Site::EvalPanic),
        schedule(&c, Site::EvalPanic),
        "different seeds must give different schedules"
    );
    // Counter triggers are deterministic by construction.
    let n = FaultPlan::parse("seed=0,shard_wedge=n3").unwrap();
    let fires: Vec<u64> = (0..9).filter(|&h| n.would_fire(Site::ShardWedge, h)).collect();
    assert_eq!(fires, vec![2, 5, 8]);
}

/// The tentpole soak: several client threads hammer a 2-shard pool while
/// ~3% of evaluation units panic (killing workers, which the supervisor
/// respawns) and the served model is hot-swapped nine times mid-flight.
/// Every request must come back exactly once, either `Ok` with weights
/// and `model_version` from one of the published versions, or the typed
/// [`ShardPanicked`]. Zero lost responses, zero mis-versioned responses.
#[test]
fn soak_under_panics_respawns_and_swaps_answers_every_request_typed() {
    let _armed = fault::arm(FaultPlan::parse("seed=42,eval_panic=p0.03").unwrap());
    let registry = ModelRegistry::single("live", fixed_class_model(0));
    let coord = Coordinator::start_pool(
        Arc::clone(&registry),
        PoolConfig {
            shards: 2,
            queue_capacity: 4096,
            batch: BatchConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(20),
            },
            default_deadline: None,
            supervisor: fast_supervisor(),
        },
    );

    const THREADS: usize = 4;
    const PER_THREAD: usize = 250;
    let img = BoolImage::blank();
    let (ok, panicked, lost) = std::thread::scope(|scope| {
        let clients: Vec<_> = (0..THREADS)
            .map(|_| {
                let (coord, img) = (&coord, &img);
                scope.spawn(move || {
                    let (mut ok, mut panicked, mut lost) = (0usize, 0usize, 0usize);
                    for _ in 0..PER_THREAD {
                        let rx = coord.submit_to(Some("live"), img.clone());
                        match rx.recv() {
                            Ok(Ok(out)) => {
                                // Any published version may serve us, but
                                // weights and version must agree.
                                let v = out.model_version.expect("pool responses carry versions");
                                assert!((1..=10).contains(&v), "unpublished version {v}");
                                assert_eq!(
                                    out.prediction as u64,
                                    v - 1,
                                    "response weights disagree with its model_version"
                                );
                                ok += 1;
                            }
                            Ok(Err(e)) if e.downcast_ref::<ShardPanicked>().is_some() => {
                                panicked += 1;
                            }
                            Ok(Err(e)) => panic!("untyped failure: {e}"),
                            Err(_) => lost += 1,
                        }
                    }
                    (ok, panicked, lost)
                })
            })
            .collect();
        // Hot-swap under fire: version k+1 predicts class k.
        for class in 1..10 {
            std::thread::sleep(Duration::from_millis(3));
            let entry = registry.swap("live", fixed_class_model(class)).unwrap();
            assert_eq!(entry.version, class as u64 + 1);
        }
        clients.into_iter().fold((0, 0, 0), |acc, h| {
            let (ok, panicked, lost) = h.join().expect("client thread panicked");
            (acc.0 + ok, acc.1 + panicked, acc.2 + lost)
        })
    });

    assert_eq!(lost, 0, "{lost} request(s) got no response");
    assert_eq!(ok + panicked, THREADS * PER_THREAD);
    assert!(panicked > 0, "p0.03 over 1000 units fired nothing — injection inert?");

    let snap = coord.shutdown();
    assert_eq!(snap.requests as usize, ok, "served-request accounting drifted");
    assert_eq!(snap.errors as usize, panicked, "error accounting drifted");
    assert!(snap.shard_panics > 0);
    assert!(snap.respawns > 0, "panicked workers were never respawned");
    assert!(
        snap.shard_health.iter().all(|&h| h != "dead"),
        "generous respawn budget must never kill a shard: {:?}",
        snap.shard_health
    );
}

/// A wedged shard (every unit sleeps far past the pool's default
/// deadline) surfaces as the typed [`DeadlineExceeded`] on the waiting
/// call — while the server-side evaluation still completes and is
/// accounted as served, because deadlines bound the *wait*, not the work.
#[test]
fn wedged_shard_trips_default_deadline_with_typed_error() {
    let _armed = fault::arm(FaultPlan::parse("seed=7,shard_wedge=n1:400").unwrap());
    let coord = Coordinator::start_pool(
        ModelRegistry::single("m", fixed_class_model(3)),
        PoolConfig {
            shards: 1,
            queue_capacity: 64,
            batch: BatchConfig {
                max_batch: 4,
                max_wait: Duration::from_micros(20),
            },
            default_deadline: Some(Duration::from_millis(50)),
            supervisor: SupervisorConfig::default(),
        },
    );
    let e = coord.classify_model(Some("m"), BoolImage::blank()).unwrap_err();
    let d = e.downcast_ref::<DeadlineExceeded>().expect("want DeadlineExceeded");
    assert_eq!(d.deadline_ms, 50);
    // Shutdown drains the wedged unit: it completes server-side and the
    // abandoned response is discarded harmlessly.
    let snap = coord.shutdown();
    assert_eq!(snap.requests, 1);
    assert_eq!(snap.errors, 0);
}

/// A crash-looping worker exhausts its respawn budget, the shard is
/// declared dead, and a reaper keeps answering the queue with the typed
/// error — clients never hang on a dead shard.
#[test]
fn crash_loop_exhausts_respawn_budget_and_reaper_answers_typed() {
    let _armed = fault::arm(FaultPlan::parse("seed=9,eval_panic=n1").unwrap());
    let coord = Coordinator::start_pool(
        ModelRegistry::single("m", fixed_class_model(0)),
        PoolConfig {
            shards: 1,
            queue_capacity: 64,
            batch: BatchConfig {
                max_batch: 1,
                max_wait: Duration::ZERO,
            },
            default_deadline: None,
            supervisor: SupervisorConfig {
                max_respawns: 2,
                respawn_window: Duration::from_secs(30),
                backoff_base: Duration::from_millis(1),
                backoff_cap: Duration::from_millis(1),
            },
        },
    );
    let img = BoolImage::blank();
    // Sequential requests: the first three die in the worker (2 respawns,
    // then the budget is spent), the rest are answered by the reaper.
    for i in 0..10 {
        let e = coord
            .submit_to(Some("m"), img.clone())
            .recv()
            .unwrap_or_else(|_| panic!("request {i} lost after shard death"))
            .unwrap_err();
        assert!(
            e.downcast_ref::<ShardPanicked>().is_some(),
            "request {i}: want ShardPanicked, got {e}"
        );
    }
    assert_eq!(coord.shard_health(), vec![ShardHealth::Dead]);
    let snap = coord.shutdown();
    assert_eq!(snap.requests, 0);
    assert_eq!(snap.errors, 10);
    assert_eq!(snap.shard_panics, 3, "only units reaching the worker count as panics");
    assert_eq!(snap.respawns, 2);
    assert_eq!(snap.shard_health, vec!["dead"]);
}
