//! Integration properties for the image-major blocked evaluator
//! (`tm::block`): blocked evaluation is bit-identical to the scalar
//! compiled plan — fired sets, class sums and argmax — across patch
//! geometries and ragged block sizes, and a trainer whose per-epoch test
//! pass runs through the block evaluator exports bit-identical models to
//! one evaluated scalar.

use convcotm::data::{BoolImage, Geometry};
use convcotm::model_io::to_wire;
use convcotm::tm::{BlockEval, ClausePlan, Engine, EvalScratch, Model, Params, Trainer};
use convcotm::util::Xoshiro256ss;

/// A model with the block path's edge cases baked in: clause 0 empty
/// (forced non-firing at inference), clause 1 thermometer-only, clause 2
/// a contradictory feature/negation pair (never fires), the rest random.
fn random_model(g: Geometry, seed: u64) -> Model {
    let params = Params::for_geometry(g);
    let o = params.literals / 2;
    let mut rng = Xoshiro256ss::new(seed);
    let mut m = Model::blank(params.clone());
    for j in 0..params.clauses {
        match j {
            0 => {}
            1 => {
                m.set_include(j, o - 1, true);
                m.set_include(j, 2 * o - 2, true);
            }
            2 => {
                m.set_include(j, 3, true);
                m.set_include(j, o + 3, true);
            }
            _ => {
                for _ in 0..1 + rng.usize_below(5) {
                    m.set_include(j, rng.usize_below(params.literals), true);
                }
            }
        }
        for i in 0..params.classes {
            m.set_weight(i, j, (rng.below(61) as i32 - 30) as i8);
        }
    }
    m
}

fn random_images(g: Geometry, seed: u64, n: usize) -> Vec<BoolImage> {
    let mut rng = Xoshiro256ss::new(seed);
    let side = g.img_side;
    (0..n)
        .map(|_| {
            let density = if rng.chance(0.5) { 0.55 } else { 0.15 };
            BoolImage::from_bools(
                &(0..side * side).map(|_| rng.chance(density)).collect::<Vec<_>>(),
            )
        })
        .collect()
}

fn geometries() -> Vec<Geometry> {
    vec![
        Geometry::asic(),
        Geometry::new(28, 10, 2).unwrap(),
        Geometry::cifar10(),
    ]
}

/// Blocked ≡ scalar over every geometry × block size, including ragged
/// tails (37 images never divides evenly into 7/8/31/64-image blocks):
/// same argmax, same class sums, same per-clause fired set per image.
#[test]
fn blocked_equals_scalar_plan_across_geometries_and_block_sizes() {
    let engine = Engine::new();
    for (gi, g) in geometries().into_iter().enumerate() {
        let model = random_model(g, 100 + gi as u64);
        let plan = ClausePlan::compile(&model);
        let block = BlockEval::compile(&plan);
        let images = random_images(g, 200 + gi as u64, 37);
        let refs: Vec<&BoolImage> = images.iter().collect();
        let mut blocked = EvalScratch::new();
        let mut scalar = EvalScratch::new();
        for b in [1usize, 7, 8, 31, 32, 64] {
            let preds = engine
                .classify_block_with(&block, &refs, b, &mut blocked)
                .to_vec();
            assert_eq!(preds.len(), refs.len());
            for (i, img) in images.iter().enumerate() {
                let want = plan.classify_into(img, &mut scalar);
                assert_eq!(preds[i], want, "argmax diverged ({g}, B={b}, image {i})");
                assert_eq!(
                    blocked.block().class_sums(i),
                    scalar.class_sums(),
                    "class sums diverged ({g}, B={b}, image {i})"
                );
                for j in 0..plan.clauses() {
                    assert_eq!(
                        blocked.block().clause_fired(j, i),
                        scalar.clause_outputs().get(j),
                        "fired set diverged ({g}, B={b}, image {i}, clause {j})"
                    );
                }
            }
        }
    }
}

/// Batch sizes around the chunk boundaries (1, just below/above one block,
/// one block plus a remainder) all evaluate identically to the scalar
/// plan at the default block size.
#[test]
fn ragged_batch_sizes_match_scalar_at_default_block() {
    let engine = Engine::new();
    let g = Geometry::asic();
    let model = random_model(g, 300);
    let plan = ClausePlan::compile(&model);
    let block = BlockEval::compile(&plan);
    let images = random_images(g, 301, 65);
    let mut blocked = EvalScratch::new();
    let mut scalar = EvalScratch::new();
    for n in [1usize, 3, 9, 33, 65] {
        let refs: Vec<&BoolImage> = images[..n].iter().collect();
        let preds = engine
            .classify_block_with(&block, &refs, convcotm::tm::DEFAULT_BLOCK, &mut blocked)
            .to_vec();
        for (i, img) in images[..n].iter().enumerate() {
            assert_eq!(preds[i], plan.classify_into(img, &mut scalar), "n={n}, image {i}");
            assert_eq!(blocked.block().class_sums(i), scalar.class_sums(), "n={n}, image {i}");
        }
    }
}

/// Two trainers stepped identically, one running its per-epoch test pass
/// through the block evaluator and one through the scalar engine, export
/// bit-identical models and report the same accuracy every epoch: the
/// blocked pass is a pure read of the plan (no RNG, no automata access).
#[test]
fn block_eval_epochs_export_bit_identical_models() {
    let params = Params::tiny();
    let g = params.geometry;
    let mut rng = Xoshiro256ss::new(400);
    let split: Vec<(BoolImage, u8)> = random_images(g, 401, 48)
        .into_iter()
        .map(|img| {
            let label = rng.below(params.classes as u32) as u8;
            (img, label)
        })
        .collect();
    let engine = Engine::new();
    let mut blocked = Trainer::new(params.clone(), 7);
    let mut scalar = Trainer::new(params.clone(), 7);
    for epoch in 0..3 {
        blocked.epoch(&split, epoch);
        scalar.epoch(&split, epoch);
        let acc_blocked = blocked.accuracy_blocked(&split);
        let exported = scalar.export();
        let acc_scalar = engine.accuracy(&exported, &split);
        assert_eq!(acc_blocked, acc_scalar, "epoch {epoch}");
        assert_eq!(
            to_wire(&blocked.export()),
            to_wire(&exported),
            "models diverged after epoch {epoch}"
        );
    }
}
