//! API conformance over real sockets: every route in the declarative
//! [`ROUTES`] table answers wrong methods with `405` + `Allow`, every
//! deprecated alias answers canonically plus `Deprecation: true`, and
//! every induced failure — malformed wire bytes, bad payloads, a wedged
//! or panicking backend (via `util::fault`), a registry-less server —
//! speaks the uniform envelope with a stable code from [`ERROR_CODES`].
//!
//! [`ROUTES`]: convcotm::server::ROUTES
//! [`ERROR_CODES`]: convcotm::server::http::ERROR_CODES

use convcotm::coordinator::{
    Backend, BackendOutput, BatchConfig, Coordinator, ModelRegistry, PoolConfig,
};
use convcotm::data::{BoolImage, Geometry};
use convcotm::server::http::{write_request, ERROR_CODES};
use convcotm::server::proto::{classify_request_body, parse_error_body, ApiError};
use convcotm::server::{
    ClientResponse, HttpConn, HttpServer, Limits, ServerConfig, ServerState, ROUTES,
};
use convcotm::tm::{Model, Params};
use convcotm::util::fault::{self, FaultPlan};
use convcotm::util::Json;
use std::io::Write as _;
use std::net::{SocketAddr, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Socket tests are timing-sensitive; keep them serial within this binary.
static HEAVY: Mutex<()> = Mutex::new(());

fn heavy_guard() -> std::sync::MutexGuard<'static, ()> {
    HEAVY.lock().unwrap_or_else(|e| e.into_inner())
}

fn fixed_class_model(class: usize) -> Model {
    let p = Params::asic();
    let mut m = Model::blank(p.clone());
    m.set_include(0, p.geometry.num_features(), true);
    m.set_weight(class, 0, 5);
    m
}

fn start_pool_server() -> (HttpServer, Arc<ServerState>, Arc<Coordinator>) {
    let coord = Arc::new(Coordinator::start_pool(
        ModelRegistry::single("m", fixed_class_model(2)),
        PoolConfig {
            shards: 1,
            queue_capacity: 256,
            batch: BatchConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(50),
            },
            ..PoolConfig::default()
        },
    ));
    let state = ServerState::new(Arc::clone(&coord));
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        http_workers: 2,
        read_timeout: Duration::from_millis(250),
        ..ServerConfig::default()
    };
    let server = HttpServer::start(&cfg, Arc::clone(&state)).expect("bind loopback");
    (server, state, coord)
}

fn drain(server: HttpServer, state: Arc<ServerState>, coord: Arc<Coordinator>) {
    server.request_shutdown();
    server.join();
    drop(state);
    if let Ok(coord) = Arc::try_unwrap(coord) {
        coord.shutdown();
    }
}

fn connect(addr: SocketAddr) -> HttpConn<TcpStream> {
    let stream = TcpStream::connect(addr).expect("connect to loopback server");
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.set_nodelay(true).unwrap();
    HttpConn::new(stream)
}

fn roundtrip(
    conn: &mut HttpConn<TcpStream>,
    method: &str,
    path: &str,
    body: &[u8],
) -> ClientResponse {
    write_request(conn.get_mut(), method, path, body, true).expect("write request");
    conn.read_response(&Limits::default())
        .expect("read response")
        .expect("server closed connection before responding")
}

/// The conformance core: a non-2xx response must be the uniform envelope
/// and its `(code, status)` pair must be in the documented inventory.
fn assert_envelope(resp: &ClientResponse) -> ApiError {
    assert!(
        resp.status >= 400,
        "assert_envelope on a {} response",
        resp.status
    );
    let e = parse_error_body(&resp.body).unwrap_or_else(|| {
        panic!(
            "HTTP {} body is not the uniform envelope: {}",
            resp.status,
            String::from_utf8_lossy(&resp.body)
        )
    });
    assert!(
        ERROR_CODES.iter().any(|(c, s, _)| *c == e.code && *s == resp.status),
        "({}, {}) is not a documented (code, status) pair",
        e.code,
        resp.status
    );
    e
}

/// Every route × every spelling × a wrong method: `405` with the `Allow`
/// header naming the right method and the `method_not_allowed` envelope;
/// alias spellings additionally carry `Deprecation: true`.
#[test]
fn every_route_rejects_wrong_methods_with_allow_and_envelope() {
    let _serial = heavy_guard();
    let (server, state, coord) = start_pool_server();
    let mut conn = connect(server.local_addr());
    for route in ROUTES {
        let wrong = if route.method == "GET" { "POST" } else { "GET" };
        let spellings =
            std::iter::once((route.path, false)).chain(route.aliases.iter().map(|&a| (a, true)));
        for (path, is_alias) in spellings {
            let resp = roundtrip(&mut conn, wrong, path, b"");
            assert_eq!(resp.status, 405, "{wrong} {path}");
            assert_eq!(resp.header("allow"), Some(route.method), "{wrong} {path}");
            let e = assert_envelope(&resp);
            assert_eq!(e.code, "method_not_allowed", "{wrong} {path}");
            let dep = resp.header("deprecation");
            assert_eq!(dep, if is_alias { Some("true") } else { None }, "{wrong} {path}");
        }
    }
    drain(server, state, coord);
}

/// Deprecated alias paths answer byte-identically to their canonical
/// spelling, modulo the `Deprecation: true` header.
#[test]
fn aliases_answer_canonically_plus_deprecation_header() {
    let _serial = heavy_guard();
    let (server, state, coord) = start_pool_server();
    let mut conn = connect(server.local_addr());

    // An empty manifest is a clean, side-effect-free 400 on both paths.
    let canon = roundtrip(&mut conn, "POST", "/v1/admin/models", b"");
    let alias = roundtrip(&mut conn, "POST", "/admin/models", b"");
    assert_eq!(canon.status, 400);
    assert_eq!(alias.status, 400);
    assert_eq!(canon.body, alias.body, "alias and canonical bodies diverge");
    assert_eq!(canon.header("deprecation"), None);
    assert_eq!(alias.header("deprecation"), Some("true"));
    assert_eq!(assert_envelope(&alias).code, "bad_manifest");

    // The deprecated shutdown spelling still drains — and is marked.
    let resp = roundtrip(&mut conn, "POST", "/admin/shutdown", b"");
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("deprecation"), Some("true"));
    let v = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    assert_eq!(v.get("draining").and_then(Json::as_bool), Some(true));
    drain(server, state, coord);
}

/// `GET /v1/models` — the read-only inventory added with the v1 surface.
#[test]
fn v1_models_lists_the_serving_inventory() {
    let _serial = heavy_guard();
    let (server, state, coord) = start_pool_server();
    let mut conn = connect(server.local_addr());
    let resp = roundtrip(&mut conn, "GET", "/v1/models", b"");
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    let v = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    let models = v.get("models").and_then(Json::as_arr).unwrap();
    assert_eq!(models.len(), 1);
    assert_eq!(models[0].get("name").and_then(Json::as_str), Some("m"));
    assert_eq!(models[0].get("version").and_then(Json::as_f64), Some(1.0));
    assert_eq!(models[0].get("geometry").and_then(Json::as_str), Some("28x28"));
    assert_eq!(v.get("shards").and_then(Json::as_f64), Some(1.0));
    drain(server, state, coord);
}

/// Structured payload failures: each maps to its stable code.
#[test]
fn payload_failures_map_to_stable_codes() {
    let _serial = heavy_guard();
    let (server, state, coord) = start_pool_server();
    let addr = server.local_addr();
    let mut conn = connect(addr);

    let resp = roundtrip(&mut conn, "GET", "/no/such/endpoint", b"");
    assert_eq!(resp.status, 404);
    assert_eq!(assert_envelope(&resp).code, "not_found");

    let resp = roundtrip(&mut conn, "POST", "/v1/classify", b"{not json");
    assert_eq!(resp.status, 400);
    assert_eq!(assert_envelope(&resp).code, "bad_request");

    // Wrong image size against the 28x28 model: the typed BadGeometry.
    let img32 = BoolImage::blank_sized(32);
    let body = classify_request_body(Some("m"), &[&img32]);
    let resp = roundtrip(&mut conn, "POST", "/v1/classify", &body);
    assert_eq!(resp.status, 400);
    let e = assert_envelope(&resp);
    assert_eq!(e.code, "bad_geometry");
    assert!(e.message.contains("32x32"), "{}", e.message);

    let img = BoolImage::blank();
    let body = classify_request_body(Some("ghost"), &[&img]);
    let resp = roundtrip(&mut conn, "POST", "/v1/classify", &body);
    assert_eq!(resp.status, 404);
    assert_eq!(assert_envelope(&resp).code, "model_not_found");

    drain(server, state, coord);
}

/// Wire-level failures: each raw byte pattern maps to its stable code,
/// with the connection closed after the error response.
#[test]
fn wire_failures_map_to_stable_codes() {
    let _serial = heavy_guard();
    let (server, state, coord) = start_pool_server();
    let addr = server.local_addr();

    let raw_cases: [(&str, Vec<u8>, u16, &str); 4] = [
        (
            "http/2 preamble",
            b"GET / HTTP/2.0\r\n\r\n".to_vec(),
            505,
            "unsupported_version",
        ),
        (
            "chunked transfer",
            b"POST /v1/classify HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n".to_vec(),
            501,
            "not_implemented",
        ),
        (
            "oversized declared body",
            b"POST /v1/classify HTTP/1.1\r\ncontent-length: 999999999999\r\n\r\n".to_vec(),
            413,
            "body_too_large",
        ),
        ("oversized head", oversized_head(), 431, "head_too_large"),
    ];
    for (label, bytes, status, code) in raw_cases {
        let mut conn = connect(addr);
        conn.get_mut().write_all(&bytes).unwrap();
        let resp = conn
            .read_response(&Limits::default())
            .unwrap_or_else(|e| panic!("{label}: {e}"))
            .unwrap_or_else(|| panic!("{label}: closed before responding"));
        assert_eq!(resp.status, status, "{label}");
        assert_eq!(assert_envelope(&resp).code, code, "{label}");
        assert_eq!(resp.header("connection"), Some("close"), "{label}");
    }

    // Mid-request stall: the 408 slow-loris answer, also enveloped.
    let mut conn = connect(addr);
    conn.get_mut().write_all(b"POST /v1/cl").unwrap();
    let resp = conn
        .read_response(&Limits::default())
        .expect("a 408 response")
        .expect("a response before close");
    assert_eq!(resp.status, 408);
    assert_eq!(assert_envelope(&resp).code, "request_timeout");

    drain(server, state, coord);
}

fn oversized_head() -> Vec<u8> {
    let mut bytes = b"GET /healthz HTTP/1.1\r\nx-pad: ".to_vec();
    bytes.extend_from_slice(&vec![b'a'; 64 * 1024]);
    bytes.extend_from_slice(b"\r\n\r\n");
    bytes
}

/// A trivial registry-less backend for the `no_registry` case.
struct EchoBackend;

impl Backend for EchoBackend {
    fn name(&self) -> &'static str {
        "echo"
    }
    fn max_batch(&self) -> usize {
        4
    }
    fn geometry(&self) -> Geometry {
        Geometry::asic()
    }
    fn classify(&mut self, imgs: &[&BoolImage]) -> anyhow::Result<Vec<BackendOutput>> {
        Ok(imgs
            .iter()
            .map(|_| BackendOutput {
                prediction: 0,
                class_sums: vec![0; 10],
                sim_cycles: None,
                model_version: None,
                timing: None,
            })
            .collect())
    }
}

/// Backend-induced failures: a panicking shard (`shard_panicked` + retry
/// hint), a wedged shard past a request deadline (`deadline_exceeded`),
/// and model administration without a registry (`no_registry`). The
/// fault plans are armed through `util::fault`; the guard serializes
/// them process-wide.
#[test]
fn backend_failures_map_to_typed_envelope_codes() {
    let _serial = heavy_guard();

    // Shard panic: typed ShardPanicked → 503 shard_panicked, retryable.
    {
        let _armed = fault::arm(FaultPlan::parse("seed=3,eval_panic=n1").unwrap());
        let (server, state, coord) = start_pool_server();
        let mut conn = connect(server.local_addr());
        let img = BoolImage::blank();
        let body = classify_request_body(Some("m"), &[&img]);
        let resp = roundtrip(&mut conn, "POST", "/v1/classify", &body);
        assert_eq!(resp.status, 503, "{}", String::from_utf8_lossy(&resp.body));
        let e = assert_envelope(&resp);
        assert_eq!(e.code, "shard_panicked");
        assert_eq!(e.retry_after_ms, Some(1000));
        assert_eq!(resp.header("retry-after"), Some("1"));
        drain(server, state, coord);
    }

    // Wedged shard + tight per-request deadline: 504 deadline_exceeded.
    {
        let _armed = fault::arm(FaultPlan::parse("seed=5,shard_wedge=n1:500").unwrap());
        let (server, state, coord) = start_pool_server();
        let mut conn = connect(server.local_addr());
        let bits = vec!["0"; 784].join(",");
        let body =
            format!("{{\"model\":\"m\",\"deadline_ms\":50,\"image\":{{\"bits\":[{bits}]}}}}");
        let resp = roundtrip(&mut conn, "POST", "/v1/classify", body.as_bytes());
        assert_eq!(resp.status, 504, "{}", String::from_utf8_lossy(&resp.body));
        assert_eq!(assert_envelope(&resp).code, "deadline_exceeded");
        drain(server, state, coord);
    }

    // No registry: model administration is a typed 409.
    {
        let coord = Arc::new(Coordinator::start_with_capacity(
            || EchoBackend,
            BatchConfig {
                max_batch: 4,
                max_wait: Duration::from_micros(50),
            },
            64,
        ));
        let state = ServerState::new(Arc::clone(&coord));
        let cfg = ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            http_workers: 2,
            ..ServerConfig::default()
        };
        let server = HttpServer::start(&cfg, Arc::clone(&state)).expect("bind loopback");
        let mut conn = connect(server.local_addr());
        let resp = roundtrip(&mut conn, "POST", "/v1/admin/models", b"m = x.cctm\n");
        assert_eq!(resp.status, 409);
        assert_eq!(assert_envelope(&resp).code, "no_registry");
        // The registry-less inventory is an empty list, not an error.
        let resp = roundtrip(&mut conn, "GET", "/v1/models", b"");
        assert_eq!(resp.status, 200);
        let v = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(v.get("models").and_then(Json::as_arr).map(|m| m.len()), Some(0));
        drain(server, state, coord);
    }
}
