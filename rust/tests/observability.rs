//! Observability end to end (DESIGN.md §14): request ids mint/adopt and
//! echo on every response, trace ids propagate router → replica and show
//! up in `/v1/debug/slow` span trees, latency histograms sum exactly
//! across shards and replicas (fleet percentiles come from merged
//! buckets, never averaged percentiles), the coordinator measures
//! `queue_wait` for backlogged requests, and the Prometheus exposition is
//! conformant over a live scrape.

use convcotm::coordinator::{
    metrics::aggregate_replica_metrics, Backend, BackendOutput, BatchConfig, Coordinator, Metrics,
    ModelRegistry, PoolConfig,
};
use convcotm::data::{BoolImage, Geometry};
use convcotm::obs::{self, AtomicLogHist, HistSnapshot};
use convcotm::server::http::{write_request, write_request_with_headers};
use convcotm::server::proto::classify_request_body;
use convcotm::server::router::{spawn_health_checker, RouterConfig, RouterState};
use convcotm::server::{
    ClientResponse, HttpConn, HttpServer, Limits, ServerConfig, ServerState,
};
use convcotm::tm::{Model, Params};
use convcotm::util::Json;
use std::net::TcpStream;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Socket tests are timing-sensitive; keep them serial within this binary.
static HEAVY: Mutex<()> = Mutex::new(());

fn heavy_guard() -> std::sync::MutexGuard<'static, ()> {
    HEAVY.lock().unwrap_or_else(|e| e.into_inner())
}

fn fixed_class_model(class: usize) -> Model {
    let p = Params::asic();
    let mut m = Model::blank(p.clone());
    m.set_include(0, p.geometry.num_features(), true);
    m.set_weight(class, 0, 5);
    m
}

fn start_pool_server() -> (HttpServer, Arc<ServerState>, Arc<Coordinator>) {
    let coord = Arc::new(Coordinator::start_pool(
        ModelRegistry::single("m", fixed_class_model(2)),
        PoolConfig {
            shards: 1,
            queue_capacity: 256,
            batch: BatchConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(50),
            },
            ..PoolConfig::default()
        },
    ));
    let state = ServerState::new(Arc::clone(&coord));
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        http_workers: 2,
        ..ServerConfig::default()
    };
    let server = HttpServer::start(&cfg, Arc::clone(&state)).expect("bind loopback");
    (server, state, coord)
}

fn drain(server: HttpServer, state: Arc<ServerState>, coord: Arc<Coordinator>) {
    server.request_shutdown();
    server.join();
    drop(state);
    if let Ok(coord) = Arc::try_unwrap(coord) {
        coord.shutdown();
    }
}

fn connect(addr: &str) -> HttpConn<TcpStream> {
    let stream = TcpStream::connect(addr).expect("connect to loopback server");
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.set_nodelay(true).unwrap();
    HttpConn::new(stream)
}

fn roundtrip(
    conn: &mut HttpConn<TcpStream>,
    method: &str,
    path: &str,
    body: &[u8],
) -> ClientResponse {
    write_request(conn.get_mut(), method, path, body, true).expect("write request");
    conn.read_response(&Limits::default())
        .expect("read response")
        .expect("server closed connection before responding")
}

fn roundtrip_with_headers(
    conn: &mut HttpConn<TcpStream>,
    method: &str,
    path: &str,
    body: &[u8],
    headers: &[(&str, &str)],
) -> ClientResponse {
    write_request_with_headers(conn.get_mut(), method, path, body, true, headers)
        .expect("write request");
    conn.read_response(&Limits::default())
        .expect("read response")
        .expect("server closed connection before responding")
}

fn body_json(resp: &ClientResponse) -> Json {
    Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap()
}

fn is_minted_id(id: &str) -> bool {
    id.len() == 32 && id.bytes().all(|b| b.is_ascii_hexdigit() && !b.is_ascii_uppercase())
}

/// Every response carries `X-Request-Id`: minted (32 lowercase hex) when
/// the client sent none or garbage, adopted verbatim when the client's id
/// is well-formed, truncated when over-long. Tracing stays *disarmed*
/// here — the id contract must hold without any arming.
#[test]
fn request_ids_mint_adopt_and_echo_on_every_response() {
    let _serial = heavy_guard();
    let (server, state, coord) = start_pool_server();
    let mut conn = connect(&server.local_addr().to_string());

    let a = roundtrip(&mut conn, "GET", "/healthz", b"");
    let id_a = a.header("x-request-id").expect("minted id").to_string();
    assert!(is_minted_id(&id_a), "minted id not 32-hex: {id_a:?}");
    let b = roundtrip(&mut conn, "GET", "/healthz", b"");
    let id_b = b.header("x-request-id").unwrap().to_string();
    assert!(is_minted_id(&id_b));
    assert_ne!(id_a, id_b, "minted ids must be unique");

    // A well-formed client id is adopted verbatim — on errors too.
    for (path, body) in [("/healthz", &b""[..]), ("/v1/classify", &b"{not json"[..])] {
        let method = if body.is_empty() { "GET" } else { "POST" };
        let resp = roundtrip_with_headers(
            &mut conn,
            method,
            path,
            body,
            &[("x-request-id", "client-id_42")],
        );
        assert_eq!(
            resp.header("x-request-id"),
            Some("client-id_42"),
            "{method} {path} did not echo the client id"
        );
    }

    // Garbage (illegal characters) is replaced with a minted id.
    let resp =
        roundtrip_with_headers(&mut conn, "GET", "/healthz", b"", &[("x-request-id", "a b\"c")]);
    let echoed = resp.header("x-request-id").unwrap();
    assert!(is_minted_id(echoed), "garbage id must be re-minted: {echoed:?}");

    // Over-long ids are truncated to the 32-char cap, not rejected.
    let long = "x".repeat(48);
    let resp =
        roundtrip_with_headers(&mut conn, "GET", "/healthz", b"", &[("x-request-id", &long)]);
    assert_eq!(resp.header("x-request-id"), Some(&long[..32]));

    drain(server, state, coord);
}

/// The acceptance round-trip: a client id sent to the *router* is echoed
/// by the router and propagated to the replica, so the shared slow ring
/// holds two span trees under the same id — the router's (with a
/// `forward` stage) and the replica's (with `parse`/`eval`/`serialize`).
#[test]
fn trace_ids_round_trip_router_to_replica_span_trees() {
    let _serial = heavy_guard();
    let _armed = obs::arm(0); // every request competes for the slow ring

    let registry = || ModelRegistry::single("live", fixed_class_model(3));
    let (a, b) = (start_pool_server_with(registry()), start_pool_server_with(registry()));
    let router = start_router(vec![a.3.clone(), b.3.clone()]);

    let img = BoolImage::blank();
    let body = classify_request_body(Some("live"), &[&img]);
    let mut conn = connect(&router.server.local_addr().to_string());
    let resp = roundtrip_with_headers(
        &mut conn,
        "POST",
        "/v1/classify",
        &body,
        &[("x-request-id", "e2e-trace-1")],
    );
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    assert_eq!(resp.header("x-request-id"), Some("e2e-trace-1"));

    let resp = roundtrip(&mut conn, "GET", "/v1/debug/slow", b"");
    assert_eq!(resp.status, 200);
    let v = body_json(&resp);
    assert_eq!(v.get("armed").and_then(Json::as_bool), Some(true));
    let slow = v.get("slow").and_then(Json::as_arr).expect("slow ring");
    let stage_sets: Vec<Vec<&str>> = slow
        .iter()
        .filter(|t| t.get("request_id").and_then(Json::as_str) == Some("e2e-trace-1"))
        .map(|t| {
            t.get("stages")
                .and_then(Json::as_arr)
                .unwrap()
                .iter()
                .filter_map(|s| s.get("stage").and_then(Json::as_str))
                .collect()
        })
        .collect();
    assert!(
        stage_sets.iter().any(|s| s.contains(&"forward")),
        "no router-side span tree for the propagated id: {stage_sets:?}"
    );
    assert!(
        stage_sets
            .iter()
            .any(|s| s.contains(&"eval") && s.contains(&"parse") && s.contains(&"serialize")),
        "no replica-side span tree for the propagated id: {stage_sets:?}"
    );
    // The fan-out also collects each replica's ring under its address.
    assert!(v.get("replicas").is_some());

    kill_router(router);
    for r in [a, b] {
        drain(r.0, r.1, r.2);
    }
}

type PoolParts = (HttpServer, Arc<ServerState>, Arc<Coordinator>, String);

fn start_pool_server_with(registry: Arc<ModelRegistry>) -> PoolParts {
    let coord = Arc::new(Coordinator::start_pool(
        registry,
        PoolConfig {
            shards: 1,
            queue_capacity: 256,
            batch: BatchConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(50),
            },
            ..PoolConfig::default()
        },
    ));
    let state = ServerState::new(Arc::clone(&coord));
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        http_workers: 2,
        ..ServerConfig::default()
    };
    let server = HttpServer::start(&cfg, Arc::clone(&state)).expect("bind replica");
    let addr = server.local_addr().to_string();
    (server, state, coord, addr)
}

struct TestRouter {
    server: HttpServer,
    state: Arc<RouterState>,
    health: JoinHandle<()>,
}

fn start_router(replicas: Vec<String>) -> TestRouter {
    let state = RouterState::new(RouterConfig {
        replicas,
        health_interval: Duration::from_millis(50),
        ..RouterConfig::default()
    })
    .expect("router state");
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        http_workers: 2,
        ..ServerConfig::default()
    };
    let server = HttpServer::start(&cfg, Arc::clone(&state)).expect("bind router");
    let health = spawn_health_checker(Arc::clone(&state));
    TestRouter {
        server,
        state,
        health,
    }
}

fn kill_router(r: TestRouter) {
    r.server.request_shutdown();
    r.server.join();
    r.health.join().expect("health checker panicked");
    drop(r.state);
}

/// The merge invariant that makes fleet percentiles sound: merging
/// snapshots is *exactly* elementwise bucket addition, and a merged
/// histogram equals the histogram of the concatenated stream.
#[test]
fn histogram_merge_is_exact_bucket_addition() {
    let streams: [&[f64]; 3] = [
        &[3.0, 17.0, 250.0, 4096.0],
        &[0.4, 0.9, 12.5, 12.5, 1e6],
        &[55.0, 777.0, 9.1],
    ];
    let combined = AtomicLogHist::new();
    let mut merged = HistSnapshot::default();
    let mut total = 0u64;
    for s in streams {
        let h = AtomicLogHist::new();
        for &us in s {
            h.record(us);
            combined.record(us);
            total += 1;
        }
        merged.merge(&h.snapshot());
    }
    assert_eq!(merged, combined.snapshot(), "merge ≠ concatenated stream");
    assert_eq!(merged.count, total);
    let bucket_total: u64 = merged.buckets.iter().sum();
    assert_eq!(bucket_total, total, "every sample lands in exactly one bucket");
    // Percentiles bracket the data: p0 ≤ min sample's bucket top, p100 ≥ max.
    assert!(merged.percentile(0.0) <= 0.5);
    assert!(merged.percentile(1.0) >= 1e6);
    // Round-trip through the wire form loses nothing.
    assert_eq!(HistSnapshot::from_json(&merged.to_json()), Some(merged.clone()));
}

/// Replica aggregation (the satellite bug fix): fleet percentiles must
/// come from the *merged* histogram, raw per-replica snapshots are
/// demoted to a labeled `debug` section. Averaging the two replicas'
/// p99s here would give ~5000 µs; the merged histogram knows better.
#[test]
fn fleet_percentiles_come_from_merged_histograms_not_averaged_percentiles() {
    let fast = Metrics::for_shard(0);
    let slow = Metrics::for_shard(1);
    // 99 fast samples at ~100 µs, 1 slow at ~10 ms → fleet p50 must stay
    // near 100 µs even though the slow replica's own p50 is 10 ms.
    let fast_lat: Vec<f64> = (0..99).map(|_| 100.0).collect();
    fast.record_batch(1, &fast_lat);
    slow.record_batch(1, &[10_000.0]);
    let agg = aggregate_replica_metrics([
        ("127.0.0.1:9001", fast.snapshot().to_json()),
        ("127.0.0.1:9002", slow.snapshot().to_json()),
    ]);
    assert_eq!(agg.get("requests").and_then(Json::as_f64), Some(100.0));
    let p50 = agg
        .get("latency_p50_us")
        .and_then(Json::as_f64)
        .expect("fleet p50");
    assert!(p50 < 300.0, "fleet p50 {p50} polluted by the slow replica");
    let p99 = agg
        .get("latency_p99_us")
        .and_then(Json::as_f64)
        .expect("fleet p99");
    assert!(p99 > 5_000.0, "fleet p99 {p99} must see the slow tail");
    // The merged wire histogram counts the full fleet.
    let hist = HistSnapshot::from_json(agg.get("latency_hist").expect("merged hist")).unwrap();
    assert_eq!(hist.count, 100);
    // Raw snapshots live under "debug" now, not a top-level "replicas".
    assert!(agg.get("debug").is_some(), "per-replica snapshots not demoted");
    assert!(
        agg.get("debug").unwrap().get("127.0.0.1:9002").is_some(),
        "debug section not keyed by replica address"
    );
}

/// A backend that holds each batch long enough to back the queue up.
struct SlowBackend;

impl Backend for SlowBackend {
    fn name(&self) -> &'static str {
        "slow"
    }
    fn max_batch(&self) -> usize {
        1
    }
    fn geometry(&self) -> Geometry {
        Geometry::asic()
    }
    fn classify(&mut self, imgs: &[&BoolImage]) -> anyhow::Result<Vec<BackendOutput>> {
        std::thread::sleep(Duration::from_millis(3));
        Ok(imgs
            .iter()
            .map(|_| BackendOutput {
                prediction: 0,
                class_sums: vec![0; 10],
                sim_cycles: None,
                model_version: None,
                timing: None,
            })
            .collect())
    }
}

/// `queue_wait` is measured at the coordinator (admission → worker
/// pickup): back a single-shard queue up behind a slow backend and the
/// later requests must report a growing, positive queue wait.
#[test]
fn queue_wait_is_positive_for_backlogged_requests() {
    let coord = Coordinator::start_with_capacity(
        || SlowBackend,
        BatchConfig {
            max_batch: 1,
            max_wait: Duration::from_micros(50),
        },
        64,
    );
    let rxs: Vec<_> = (0..6).map(|_| coord.submit(BoolImage::blank())).collect();
    let outputs: Vec<BackendOutput> = rxs
        .into_iter()
        .map(|rx| rx.recv().expect("worker alive").expect("classify ok"))
        .collect();
    coord.shutdown();
    for out in &outputs {
        let t = out.timing.expect("worker stamps stage timings");
        assert!(t.eval_us > 0.0, "eval time must be positive");
        assert!(t.queue_wait_us >= 0.0);
    }
    // With a 3 ms serial backend, the last of 6 requests queued ≥ 10 ms.
    let worst = outputs
        .iter()
        .map(|o| o.timing.unwrap().queue_wait_us)
        .fold(0.0f64, f64::max);
    assert!(
        worst > 5_000.0,
        "backlogged requests reported only {worst} µs of queue wait"
    );
}

/// A live `?format=prometheus` scrape is conformant: right content type,
/// `# HELP`/`# TYPE` for every family, counters end in `_total`,
/// histograms carry cumulative `le` buckets ending at `+Inf` == `_count`.
/// (`ci/check_promtext.py` lints the same properties in CI; this is the
/// in-tree mirror so `cargo test` catches drift first.)
#[test]
fn prometheus_scrape_is_conformant_over_http() {
    let _serial = heavy_guard();
    let (server, state, coord) = start_pool_server();
    let mut conn = connect(&server.local_addr().to_string());

    // Some traffic so the counters and histograms are non-trivial.
    let img = BoolImage::blank();
    let body = classify_request_body(Some("m"), &[&img]);
    for _ in 0..3 {
        let resp = roundtrip(&mut conn, "POST", "/v1/classify", &body);
        assert_eq!(resp.status, 200);
    }

    let resp = roundtrip(&mut conn, "GET", "/v1/metrics?format=prometheus", b"");
    assert_eq!(resp.status, 200);
    assert!(
        resp.header("content-type")
            .is_some_and(|ct| ct.starts_with("text/plain; version=0.0.4")),
        "wrong exposition content type: {:?}",
        resp.header("content-type")
    );
    let text = std::str::from_utf8(&resp.body).unwrap();

    for family in [
        "convcotm_requests_total",
        "convcotm_errors_total",
        "convcotm_batches_total",
        "convcotm_request_latency_seconds",
        "convcotm_queue_wait_seconds",
        "convcotm_eval_seconds",
    ] {
        assert!(text.contains(&format!("# HELP {family} ")), "no HELP for {family}");
        assert!(text.contains(&format!("# TYPE {family} ")), "no TYPE for {family}");
    }
    // Histogram shape: +Inf bucket equals _count.
    for family in ["convcotm_request_latency_seconds"] {
        let inf = sample_value(text, &format!("{family}_bucket{{le=\"+Inf\"}}"));
        let count = sample_value(text, &format!("{family}_count"));
        assert_eq!(inf, count, "{family}: +Inf bucket must equal _count");
        assert!(count >= 3.0, "{family}: scrape missed the traffic");
    }
    // Counter naming convention: every TYPE counter family ends _total.
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let (name, kind) = (it.next().unwrap(), it.next().unwrap());
            if kind == "counter" {
                assert!(name.ends_with("_total"), "counter {name} must end in _total");
            }
        }
    }
    // The JSON spelling still answers on the same canonical path.
    let resp = roundtrip(&mut conn, "GET", "/v1/metrics", b"");
    assert_eq!(resp.status, 200);
    assert!(body_json(&resp).get("latency_hist").is_some());

    drain(server, state, coord);
}

/// First value of the sample whose line starts with `prefix` followed by
/// a space (exact family+labels match).
fn sample_value(text: &str, prefix: &str) -> f64 {
    text.lines()
        .find_map(|l| l.strip_prefix(prefix)?.strip_prefix(' ')?.parse().ok())
        .unwrap_or_else(|| panic!("no sample {prefix}"))
}
