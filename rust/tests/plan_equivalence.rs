//! Compiled-plan acceptance properties (ISSUE 2):
//!
//! 1. Plan-based evaluation ≡ direct per-patch evaluation — firing sets,
//!    class sums and argmax — across the ASIC, CIFAR-shaped and strided
//!    geometries. The direct engine is the unoptimized oracle (the chip's
//!    datapath transcription), so equality here is the "exactly in
//!    accordance" property (§V) extended to the compiled evaluation spine.
//! 2. Plan-backed training is bit-identical to the pre-plan evaluation
//!    semantics: same seed ⇒ same exported model, with the incrementally
//!    synced plan equal to a fresh compile.

use convcotm::data::{BoolImage, Geometry};
use convcotm::tm::{ClausePlan, Engine, EvalScratch, Model, Params, Trainer};
use convcotm::util::quick::{check, PropResult};
use convcotm::util::Xoshiro256ss;

/// The three geometries named by the acceptance criteria.
fn test_geometries() -> Vec<Geometry> {
    vec![
        Geometry::asic(),
        Geometry::cifar10(),
        Geometry::new(28, 10, 2).unwrap(),
    ]
}

fn random_image(rng: &mut Xoshiro256ss, g: Geometry, density: f64) -> BoolImage {
    BoolImage::from_bools(
        &(0..g.img_pixels())
            .map(|_| rng.chance(density))
            .collect::<Vec<_>>(),
    )
}

fn random_model(rng: &mut Xoshiro256ss, g: Geometry, clauses: usize) -> Model {
    let p = Params {
        clauses,
        ..Params::for_geometry(g)
    };
    let mut m = Model::blank(p.clone());
    for j in 0..p.clauses {
        // Sparse random includes (some clauses deliberately left empty).
        for _ in 0..rng.usize_below(7) {
            m.set_include(j, rng.usize_below(p.literals), true);
        }
        for i in 0..p.classes {
            m.set_weight(i, j, (rng.below(61) as i32 - 30) as i8);
        }
    }
    m
}

fn check_plan_matches_direct(g: Geometry) {
    check(
        &format!("compiled plan equals direct per-patch evaluation ({g})"),
        8,
        |gen| -> PropResult {
            let mut rng = Xoshiro256ss::new(gen.u64());
            let model = random_model(&mut rng, g, 12);
            let plan = ClausePlan::compile(&model);
            let mut scratch = EvalScratch::new();
            let density = 0.1 + 0.5 * gen.f64_unit();
            let img = random_image(&mut rng, g, density);
            let pred = plan.classify_into(&img, &mut scratch);
            // The oracle: direct per-patch evaluation (no early exit).
            let oracle = Engine { early_exit: false }.classify(&model, &img);
            // Firing sets, class sums and argmax must all agree.
            convcotm::prop_assert_eq!(scratch.clause_outputs(), &oracle.clauses);
            convcotm::prop_assert_eq!(scratch.class_sums(), &oracle.class_sums[..]);
            convcotm::prop_assert_eq!(pred, oracle.prediction);
            Ok(())
        },
    );
}

#[test]
fn plan_matches_direct_on_asic_geometry() {
    check_plan_matches_direct(Geometry::asic());
}

#[test]
fn plan_matches_direct_on_cifar_geometry() {
    check_plan_matches_direct(Geometry::cifar10());
}

#[test]
fn plan_matches_direct_on_strided_geometry() {
    check_plan_matches_direct(Geometry::new(28, 10, 2).unwrap());
}

/// Random labelled images for trainer determinism runs (learnability is
/// irrelevant — only the update-for-update RNG/feedback trajectory is).
fn random_split(g: Geometry, n: usize, seed: u64) -> Vec<(BoolImage, u8)> {
    let mut rng = Xoshiro256ss::new(seed);
    (0..n)
        .map(|_| {
            let img = random_image(&mut rng, g, 0.25);
            let label = rng.below(4) as u8;
            (img, label)
        })
        .collect()
}

fn check_trainer_seed_determinism(g: Geometry) {
    let params = Params {
        clauses: 12,
        t: 12,
        s: 4.0,
        ..Params::for_geometry(g)
    };
    let split = random_split(g, 40, 99);
    let run = |plan_enabled: bool| {
        let mut tr = Trainer::new(params.clone(), 4242);
        tr.set_plan_enabled(plan_enabled);
        for e in 0..2 {
            tr.epoch(&split, e);
        }
        assert!(
            tr.plan().is_in_sync(tr.model()),
            "plan mirror out of sync ({g}, plan_enabled={plan_enabled})"
        );
        assert!(
            *tr.plan() == ClausePlan::compile(&tr.export()),
            "incrementally synced plan differs from a fresh compile ({g})"
        );
        tr.export()
    };
    let with_plan = run(true);
    let pre_plan = run(false);
    assert!(
        with_plan == pre_plan,
        "plan-backed training must be bit-identical to the pre-plan path ({g})"
    );
}

#[test]
fn trainer_plan_path_is_bit_identical_to_pre_plan_path() {
    check_trainer_seed_determinism(Geometry::asic());
}

#[test]
fn trainer_plan_path_is_bit_identical_on_strided_geometry() {
    check_trainer_seed_determinism(Geometry::new(28, 10, 2).unwrap());
}
