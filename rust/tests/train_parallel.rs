//! Data-parallel training acceptance properties (ISSUE 4):
//!
//! 1. **Thread-count invariance** — training with `--threads 1` and
//!    `--threads 4` from the same seed exports bit-identical models: the
//!    counter-based RNG streams address every decision by its logical
//!    coordinates, so the schedule cannot leak into the result.
//! 2. **Checkpoint resume equivalence** — train 2 epochs ≡ train 1 epoch,
//!    save a v3 checkpoint, load it, train 1 more: bit-identical, even
//!    across different thread counts on each side of the checkpoint.
//! 3. **Train→serve publish** — `ModelRegistry::publish` feeds each
//!    checkpoint into a live shard pool with the zero-drop hot-swap.

use convcotm::coordinator::{BatchConfig, Coordinator, ModelRegistry, PoolConfig};
use convcotm::data::{BoolImage, Geometry};
use convcotm::model_io;
use convcotm::tm::{ClausePlan, EvalScratch, Params, Trainer};
use convcotm::util::Xoshiro256ss;
use std::sync::Arc;

/// Random labelled images (learnability is irrelevant — only the
/// update-for-update feedback trajectory is).
fn random_split(g: Geometry, n: usize, seed: u64) -> Vec<(BoolImage, u8)> {
    let mut rng = Xoshiro256ss::new(seed);
    (0..n)
        .map(|_| {
            let img = BoolImage::from_bools(
                &(0..g.img_pixels())
                    .map(|_| rng.chance(0.25))
                    .collect::<Vec<_>>(),
            );
            let label = rng.below(4) as u8;
            (img, label)
        })
        .collect()
}

fn test_params(g: Geometry) -> Params {
    Params {
        clauses: 12,
        t: 12,
        s: 4.0,
        ..Params::for_geometry(g)
    }
}

fn check_thread_invariance(g: Geometry) {
    let params = test_params(g);
    let split = random_split(g, 40, 99);
    let run = |threads: usize| {
        let mut tr = Trainer::new(params.clone(), 4242);
        tr.set_threads(threads);
        for e in 0..2 {
            tr.epoch(&split, e);
        }
        assert!(
            tr.plan().is_in_sync(tr.model()),
            "plan mirror out of sync ({g}, threads={threads})"
        );
        assert!(
            *tr.plan() == ClausePlan::compile(&tr.export()),
            "incrementally synced plan differs from a fresh compile ({g}, threads={threads})"
        );
        tr.export()
    };
    let serial = run(1);
    let four = run(4);
    assert!(
        serial == four,
        "1-thread and 4-thread training must export bit-identical models ({g})"
    );
    // Uneven shard split (12 clauses over 5 workers) — same property.
    let five = run(5);
    assert!(serial == five, "uneven shard split leaked into the model ({g})");
}

#[test]
fn thread_count_invariance_on_asic_geometry() {
    check_thread_invariance(Geometry::asic());
}

#[test]
fn thread_count_invariance_on_strided_geometry() {
    check_thread_invariance(Geometry::new(28, 10, 2).unwrap());
}

#[test]
fn checkpoint_resume_is_bit_identical() {
    let g = Geometry::asic();
    let params = test_params(g);
    let split = random_split(g, 40, 7);
    // Uninterrupted: 2 epochs straight.
    let mut straight = Trainer::new(params.clone(), 321);
    straight.epoch(&split, 0);
    straight.epoch(&split, 1);
    // Interrupted: 1 epoch, checkpoint to disk, resume, 1 more epoch.
    let mut first = Trainer::new(params.clone(), 321);
    first.epoch(&split, 0);
    let path = std::env::temp_dir().join("convcotm_train_parallel_resume.ckpt");
    model_io::save_checkpoint(&first.checkpoint(), &path).unwrap();
    let ck = model_io::load_checkpoint(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(ck.samples_seen, split.len() as u64);
    assert_eq!(ck.epochs_done, 1);
    let mut resumed = Trainer::from_checkpoint(ck);
    resumed.epoch(&split, 1);
    assert!(
        straight.export() == resumed.export(),
        "train 2 epochs must equal train 1 + resume 1, bit for bit"
    );
    assert_eq!(straight.samples_seen(), resumed.samples_seen());
}

#[test]
fn checkpoint_resume_across_thread_counts() {
    // The RNG stream position lives in the checkpoint, not the schedule:
    // a 4-thread run resumed serially (and vice versa) stays on the same
    // trajectory as an uninterrupted serial run.
    let g = Geometry::asic();
    let params = test_params(g);
    let split = random_split(g, 30, 13);
    let mut reference = Trainer::new(params.clone(), 55);
    reference.epoch(&split, 0);
    reference.epoch(&split, 1);

    let mut parallel_first = Trainer::new(params.clone(), 55);
    parallel_first.set_threads(4);
    parallel_first.epoch(&split, 0);
    let mut serial_rest = Trainer::from_checkpoint(parallel_first.checkpoint());
    serial_rest.epoch(&split, 1);
    assert!(
        reference.export() == serial_rest.export(),
        "4-thread epoch + serial resume must match the serial reference"
    );

    let mut serial_first = Trainer::new(params, 55);
    serial_first.epoch(&split, 0);
    let mut parallel_rest = Trainer::from_checkpoint(serial_first.checkpoint());
    parallel_rest.set_threads(4);
    parallel_rest.epoch(&split, 1);
    assert!(
        reference.export() == parallel_rest.export(),
        "serial epoch + 4-thread resume must match the serial reference"
    );
}

#[test]
fn predict_with_serves_a_mid_training_model_immutably() {
    let g = Geometry::asic();
    let params = test_params(g);
    let split = random_split(g, 30, 3);
    let mut tr = Trainer::new(params, 9);
    tr.epoch(&split, 0);
    // A "serving-side" evaluation with an external arena needs no mutable
    // trainer access and matches the exported model's inference.
    let exported = tr.export();
    let mut scratch = EvalScratch::new();
    let engine = convcotm::tm::Engine::new();
    for (img, _) in split.iter().take(10) {
        assert_eq!(
            tr.predict_with(img, &mut scratch),
            engine.classify(&exported, img).prediction
        );
    }
}

#[test]
fn training_checkpoints_hot_swap_into_a_live_pool() {
    // The train→serve loop: each checkpoint is published into the
    // registry behind a running shard pool; requests keep succeeding
    // across the swap and versions advance.
    let g = Geometry::asic();
    let params = test_params(g);
    let split = random_split(g, 30, 17);
    let registry = Arc::new(ModelRegistry::new());
    let coord = Coordinator::start_pool(
        Arc::clone(&registry),
        PoolConfig {
            shards: 2,
            queue_capacity: 64,
            batch: BatchConfig::default(),
            ..PoolConfig::default()
        },
    );
    let mut tr = Trainer::new(params, 29);
    for e in 0..3 {
        tr.epoch(&split, e);
        let entry = registry.publish("live", tr.export()).unwrap();
        assert_eq!(entry.version, e as u64 + 1, "publish bumps the version");
        // The pool serves the just-published version without drops.
        let rxs: Vec<_> = split
            .iter()
            .take(16)
            .map(|(img, _)| coord.submit_to(Some("live"), img.clone()))
            .collect();
        for rx in rxs {
            rx.recv().unwrap().expect("request served across hot-swap");
        }
    }
    let snap = coord.shutdown();
    assert_eq!(snap.requests, 48, "every probe across 3 swaps was served");
}
