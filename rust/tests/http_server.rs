//! End-to-end tests for the HTTP front door (`server`): property tests
//! over the request parser (never panics, maps every malformed input to a
//! 4xx/5xx), and loopback tests proving the acceptance criteria —
//! concurrent keep-alive correctness against a 4-shard pool, lossless
//! hot-swap via `POST /admin/models` under sustained load with zero
//! mis-versioned responses, deterministic `503` + `Retry-After` shedding
//! on saturated queues, and a clean drain through `POST /admin/shutdown`.

use convcotm::coordinator::{
    Backend, BackendOutput, BatchConfig, Coordinator, ModelRegistry, PoolConfig,
};
use convcotm::data::{BoolImage, Geometry};
use convcotm::server::http::write_request;
use convcotm::server::{ClientResponse, HttpConn, HttpServer, Limits, ServerConfig, ServerState};
use convcotm::tm::{Engine, Model, Params};
use convcotm::util::quick::{check, PropResult};
use convcotm::util::{Json, Xoshiro256ss};
use std::io::Cursor;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Socket tests are timing-sensitive enough (drains, timeouts) that the
/// parallel test runner must not interleave them.
static HEAVY: Mutex<()> = Mutex::new(());

fn heavy_guard() -> std::sync::MutexGuard<'static, ()> {
    HEAVY.lock().unwrap_or_else(|e| e.into_inner())
}

fn random_model(seed: u64, includes_per_clause: usize) -> Model {
    let params = Params::asic();
    let mut rng = Xoshiro256ss::new(seed);
    let mut m = Model::blank(params.clone());
    for j in 0..params.clauses {
        for _ in 0..1 + rng.usize_below(includes_per_clause) {
            m.set_include(j, rng.usize_below(params.literals), true);
        }
        for i in 0..params.classes {
            m.set_weight(i, j, (rng.below(61) as i32 - 30) as i8);
        }
    }
    m
}

fn random_images(seed: u64, n: usize) -> Vec<BoolImage> {
    let mut rng = Xoshiro256ss::new(seed);
    (0..n)
        .map(|_| BoolImage::from_bools(&(0..784).map(|_| rng.chance(0.3)).collect::<Vec<_>>()))
        .collect()
}

/// Deterministically predicts `class` on a blank image (one clause over a
/// negated content literal, +5 vote) — the hot-swap oracle.
fn fixed_class_model(class: usize) -> Model {
    let p = Params::asic();
    let mut m = Model::blank(p.clone());
    m.set_include(0, p.geometry.num_features(), true);
    m.set_weight(class, 0, 5);
    m
}

fn start_pool_server(
    registry: Arc<ModelRegistry>,
    shards: usize,
    queue_capacity: usize,
    read_timeout: Duration,
) -> (HttpServer, Arc<ServerState>, Arc<Coordinator>) {
    let coord = Arc::new(Coordinator::start_pool(
        registry,
        PoolConfig {
            shards,
            queue_capacity,
            batch: BatchConfig {
                max_batch: 16,
                max_wait: Duration::from_micros(50),
            },
            ..PoolConfig::default()
        },
    ));
    let state = ServerState::new(Arc::clone(&coord));
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        http_workers: 4,
        read_timeout,
        ..ServerConfig::default()
    };
    let server = HttpServer::start(&cfg, Arc::clone(&state)).expect("bind loopback");
    (server, state, coord)
}

/// Drain the server, then the pool, returning the final pool snapshot.
fn drain(
    server: HttpServer,
    state: Arc<ServerState>,
    coord: Arc<Coordinator>,
) -> convcotm::coordinator::MetricsSnapshot {
    server.request_shutdown();
    server.join();
    drop(state);
    match Arc::try_unwrap(coord) {
        Ok(coord) => coord.shutdown(),
        Err(coord) => coord.metrics(),
    }
}

fn connect(addr: SocketAddr) -> HttpConn<TcpStream> {
    let stream = TcpStream::connect(addr).expect("connect to loopback server");
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    stream.set_nodelay(true).unwrap();
    HttpConn::new(stream)
}

/// One keep-alive request/response exchange.
fn roundtrip(
    conn: &mut HttpConn<TcpStream>,
    method: &str,
    path: &str,
    body: &[u8],
) -> ClientResponse {
    write_request(conn.get_mut(), method, path, body, true).expect("write request");
    conn.read_response(&Limits::default())
        .expect("read response")
        .expect("server closed connection before responding")
}

fn body_json(resp: &ClientResponse) -> Json {
    Json::parse(std::str::from_utf8(&resp.body).expect("utf-8 body")).expect("json body")
}

/// The wire shape comes from the library's own client-side builder, so
/// these tests and the server share one definition of the format.
fn classify_body(model: Option<&str>, imgs: &[&BoolImage]) -> Vec<u8> {
    convcotm::server::proto::classify_request_body(model, imgs)
}

// ---------------------------------------------------------------------
// Property tests: the parser under hostile input (no sockets involved).
// ---------------------------------------------------------------------

/// Arbitrary byte soup, biased toward HTTP-shaped fragments so the deep
/// parse paths (request line, headers, content-length) are exercised, not
/// just the "no CRLFCRLF" early exit.
fn garbage_request(g: &mut convcotm::util::quick::Gen) -> Vec<u8> {
    const FRAGMENTS: &[&[u8]] = &[
        b"GET ",
        b"POST ",
        b"/v1/classify",
        b"/",
        b" HTTP/1.1",
        b" HTTP/1.0",
        b" HTTP/9.9",
        b"\r\n",
        b"\n",
        b"\r",
        b"content-length: ",
        b"content-length: 18446744073709551616",
        b"transfer-encoding: chunked",
        b"connection: close",
        b": ",
        b"\r\n\r\n",
        b"{\"images\":[",
        b"\x00\xff\xfe",
    ];
    let mut out = Vec::new();
    let pieces = g.usize_in(0, 24);
    for _ in 0..pieces {
        if g.chance(0.7) {
            out.extend_from_slice(FRAGMENTS[g.usize_in(0, FRAGMENTS.len() - 1)]);
        } else {
            let len = g.usize_in(0, 48);
            for _ in 0..len {
                out.push(g.usize_in(0, 255) as u8);
            }
        }
    }
    out
}

#[test]
fn parser_never_panics_and_maps_garbage_to_4xx_5xx() {
    let limits = Limits {
        max_head_bytes: 512,
        max_body_bytes: 1024,
        ..Limits::default()
    };
    check("http parser total on garbage", 400, |g| -> PropResult {
        let bytes = garbage_request(g);
        let mut conn = HttpConn::new(Cursor::new(bytes.clone()));
        match conn.read_request(&limits) {
            // Garbage can accidentally form a valid request — fine.
            Ok(_) => Ok(()),
            Err(e) => {
                let status = e.status();
                convcotm::prop_assert!(
                    matches!(status, Some(400..=599)),
                    "error '{e}' on {} bytes maps to {status:?}, not a response status",
                    bytes.len()
                );
                Ok(())
            }
        }
    });
}

#[test]
fn truncated_requests_always_fail_with_400_never_panic() {
    check("http parser on truncations", 60, |g| -> PropResult {
        let n_body = g.usize_in(0, 200);
        let body: Vec<u8> = (0..n_body).map(|_| g.usize_in(0, 255) as u8).collect();
        let mut wire = Vec::new();
        write_request(&mut wire, "POST", "/v1/classify", &body, g.bool()).unwrap();
        // Any strict prefix must parse to a clean 400 (closed mid-head or
        // mid-body), and the full request must parse.
        let cut = g.usize_in(1, wire.len() - 1);
        let mut conn = HttpConn::new(Cursor::new(wire[..cut].to_vec()));
        match conn.read_request(&Limits::default()) {
            Err(e) => convcotm::prop_assert_eq!(e.status(), Some(400)),
            other => return Err(format!("cut at {cut}/{} parsed as {other:?}", wire.len())),
        }
        let full = HttpConn::new(Cursor::new(wire)).read_request(&Limits::default());
        convcotm::prop_assert!(
            matches!(&full, Ok(Some(req)) if req.body == body),
            "full request failed to parse: {full:?}"
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Loopback tests: the full server against real sockets.
// ---------------------------------------------------------------------

/// Acceptance: concurrent keep-alive clients against a 4-shard pool all
/// receive correct classifications (bit-identical to the local engine)
/// with the serving model version attached.
#[test]
fn concurrent_keep_alive_clients_get_correct_classifications() {
    let _serial = heavy_guard();
    let model = random_model(31, 5);
    let (server, state, coord) = start_pool_server(
        ModelRegistry::single("m", model.clone()),
        4,
        4096,
        Duration::from_secs(2),
    );
    let addr = server.local_addr();
    let engine = Engine::new();
    let n_clients = 4usize;
    let per_client = 20usize;
    let batch = 3usize;
    std::thread::scope(|scope| {
        for c in 0..n_clients {
            let model = &model;
            let engine = &engine;
            scope.spawn(move || {
                let images = random_images(100 + c as u64, per_client * batch);
                let mut conn = connect(addr);
                for r in 0..per_client {
                    let chunk: Vec<&BoolImage> =
                        images[r * batch..(r + 1) * batch].iter().collect();
                    let body = classify_body(Some("m"), &chunk);
                    let resp = roundtrip(&mut conn, "POST", "/v1/classify", &body);
                    assert_eq!(resp.status, 200, "{:?}", String::from_utf8_lossy(&resp.body));
                    let v = body_json(&resp);
                    let results = v.get("results").and_then(Json::as_arr).unwrap();
                    assert_eq!(results.len(), batch);
                    for (img, res) in chunk.iter().zip(results) {
                        let class = res.get("class").and_then(Json::as_f64).unwrap() as u8;
                        assert_eq!(class, engine.classify(model, img).prediction);
                        let version = res.get("model_version").and_then(Json::as_f64).unwrap();
                        assert_eq!(version, 1.0);
                        let sums = res.get("class_sums").and_then(Json::as_arr).unwrap();
                        assert_eq!(sums.len(), 10);
                    }
                }
            });
        }
    });
    // Keep-alive held: one connection per client, every request counted.
    let conns = state.stats.connections.load(Ordering::Relaxed);
    assert_eq!(conns, n_clients as u64, "connections were not reused");
    let served = (n_clients * per_client * batch) as u64;
    assert_eq!(state.stats.requests.load(Ordering::Relaxed), (n_clients * per_client) as u64);
    let snap = drain(server, state, coord);
    assert_eq!(snap.requests, served);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.per_model["m"].requests, served);
}

/// Acceptance: a `POST /admin/models` hot-swap under sustained load
/// completes with zero dropped and zero mis-versioned responses —
/// prediction and `model_version` always agree, and requests after the
/// admin call returns are all served by the new version. Eviction through
/// the same manifest body then 404s subsequent requests.
#[test]
fn admin_hot_swap_under_load_is_lossless_and_versioned() {
    let _serial = heavy_guard();
    let dir = std::env::temp_dir().join("convcotm_http_swap_test");
    std::fs::create_dir_all(&dir).unwrap();
    let v2_path = dir.join("v2.cctm");
    convcotm::model_io::save_file(&fixed_class_model(7), &v2_path).unwrap();

    let (server, state, coord) = start_pool_server(
        ModelRegistry::single("live", fixed_class_model(2)),
        2,
        4096,
        Duration::from_secs(2),
    );
    let addr = server.local_addr();
    let stop = AtomicBool::new(false);
    let img = BoolImage::blank();
    let observed: Mutex<Vec<(u8, u64)>> = Mutex::new(Vec::new());
    /// Sets the stop flag even on an assertion panic, so the loader
    /// threads exit and the scope join cannot hang a failing test.
    struct StopOnDrop<'a>(&'a AtomicBool);
    impl Drop for StopOnDrop<'_> {
        fn drop(&mut self) {
            self.0.store(true, Ordering::Relaxed);
        }
    }
    std::thread::scope(|scope| {
        let _stop_guard = StopOnDrop(&stop);
        for _ in 0..2 {
            let (stop, observed, img) = (&stop, &observed, &img);
            scope.spawn(move || {
                let mut conn = connect(addr);
                let body = classify_body(Some("live"), &[img]);
                while !stop.load(Ordering::Relaxed) {
                    let resp = roundtrip(&mut conn, "POST", "/v1/classify", &body);
                    assert_eq!(resp.status, 200, "request dropped during hot-swap");
                    let v = body_json(&resp);
                    let res = &v.get("results").and_then(Json::as_arr).unwrap()[0];
                    let class = res.get("class").and_then(Json::as_f64).unwrap() as u8;
                    let version = res.get("model_version").and_then(Json::as_f64).unwrap() as u64;
                    observed.lock().unwrap().push((class, version));
                }
            });
        }
        // Let traffic build, then deploy v2 through the admin endpoint.
        std::thread::sleep(Duration::from_millis(60));
        let mut admin = connect(addr);
        let manifest = format!("live = {}\n", v2_path.display());
        let resp = roundtrip(&mut admin, "POST", "/admin/models", manifest.as_bytes());
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let v = body_json(&resp);
        assert_eq!(
            v.get("published").and_then(|p| p.get("live")).and_then(Json::as_f64),
            Some(2.0)
        );
        // A request submitted after the admin call returned must be served
        // by v2 (the §8 ordering guarantee, across the network edge).
        let resp =
            roundtrip(&mut admin, "POST", "/v1/classify", &classify_body(Some("live"), &[&img]));
        let v = body_json(&resp);
        let res = &v.get("results").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(res.get("class").and_then(Json::as_f64), Some(7.0));
        assert_eq!(res.get("model_version").and_then(Json::as_f64), Some(2.0));
        std::thread::sleep(Duration::from_millis(60));
        stop.store(true, Ordering::Relaxed);
    });
    let observed = observed.into_inner().unwrap();
    assert!(observed.len() > 20, "load generators only made {} requests", observed.len());
    for (class, version) in &observed {
        assert!(
            (*class, *version) == (2, 1) || (*class, *version) == (7, 2),
            "mis-versioned response: class {class} with version {version}"
        );
    }
    assert!(
        observed.iter().any(|&(c, _)| c == 2) && observed.iter().any(|&(c, _)| c == 7),
        "load did not straddle the swap (observed {} responses)",
        observed.len()
    );

    // Evict via the same manifest format; the model then 404s.
    let mut admin = connect(addr);
    let resp = roundtrip(&mut admin, "POST", "/admin/models", b"live = -\n");
    assert_eq!(resp.status, 200);
    let v = body_json(&resp);
    assert_eq!(v.get("evicted").and_then(Json::as_arr).map(|a| a.len()), Some(1));
    let resp =
        roundtrip(&mut admin, "POST", "/v1/classify", &classify_body(Some("live"), &[&img]));
    assert_eq!(resp.status, 404, "{}", String::from_utf8_lossy(&resp.body));
    let snap = drain(server, state, coord);
    // Pool accounting: every load-generator single plus the post-swap
    // check served; the post-evict request is the one error.
    assert_eq!(snap.requests as usize, observed.len() + 1);
    assert_eq!(snap.errors, 1);
    std::fs::remove_dir_all(&dir).ok();
}

/// Regression: a single bad image inside a batch fails alone. The batch
/// travels as one coordinator block, so this exercises the block path's
/// per-image isolation end to end: `200` with an `{"error": ...}` slot for
/// the bad image, correct classifications for the rest — while an
/// all-failed batch (unknown model) still maps to its status code.
#[test]
fn bad_image_in_batch_fails_alone_with_200() {
    let _serial = heavy_guard();
    let model = random_model(91, 5);
    let (server, state, coord) = start_pool_server(
        ModelRegistry::single("m", model.clone()),
        2,
        4096,
        Duration::from_secs(2),
    );
    let addr = server.local_addr();
    let engine = Engine::new();
    let images = random_images(92, 9);
    let bad = BoolImage::blank_sized(32);
    let mut refs: Vec<&BoolImage> = images.iter().collect();
    refs.insert(4, &bad);
    let mut conn = connect(addr);
    let resp = roundtrip(&mut conn, "POST", "/v1/classify", &classify_body(Some("m"), &refs));
    assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
    let v = body_json(&resp);
    assert_eq!(v.get("errors").and_then(Json::as_f64), Some(1.0));
    let results = v.get("results").and_then(Json::as_arr).unwrap();
    assert_eq!(results.len(), 10);
    for (i, res) in results.iter().enumerate() {
        if i == 4 {
            // The slot carries the same uniform envelope a whole-call
            // failure would: a stable code plus a human message.
            let err = res.get("error").expect("error envelope in the bad slot");
            assert_eq!(err.get("code").and_then(Json::as_str), Some("bad_geometry"));
            let msg = err.get("message").and_then(Json::as_str).unwrap();
            assert!(msg.contains("32x32"), "{msg}");
        } else {
            assert!(res.get("error").is_none());
            let class = res.get("class").and_then(Json::as_f64).unwrap() as u8;
            assert_eq!(class, engine.classify(&model, refs[i]).prediction);
        }
    }

    // Every image failing (unknown model) keeps the status mapping.
    let resp = roundtrip(
        &mut conn,
        "POST",
        "/v1/classify",
        &classify_body(Some("ghost"), &refs[..3]),
    );
    assert_eq!(resp.status, 404, "{}", String::from_utf8_lossy(&resp.body));

    let snap = drain(server, state, coord);
    assert_eq!(snap.requests, 9);
    assert_eq!(snap.errors, 4, "one bad image + three unknown-model images");
    assert_eq!(snap.per_model["m"].errors, 1);
    assert_eq!(snap.per_model["ghost"].errors, 3);
}

/// A backend that parks inside `classify` until released — makes the
/// full-queue state deterministic for the shedding test.
struct GateBackend {
    geometry: Geometry,
    gate: std::sync::mpsc::Receiver<()>,
}

impl Backend for GateBackend {
    fn name(&self) -> &'static str {
        "gate"
    }
    fn max_batch(&self) -> usize {
        1
    }
    fn geometry(&self) -> Geometry {
        self.geometry
    }
    fn classify(&mut self, imgs: &[&BoolImage]) -> anyhow::Result<Vec<BackendOutput>> {
        let _ = self.gate.recv();
        Ok(imgs
            .iter()
            .map(|_| BackendOutput {
                prediction: 0,
                class_sums: vec![0; 10],
                sim_cycles: None,
                model_version: None,
                timing: None,
            })
            .collect())
    }
}

/// Acceptance: saturating the bounded queues yields `503` with a
/// `Retry-After` header — never a hang, never a panic. Deterministic: the
/// evaluator is wedged shut while the queue is filled.
#[test]
fn saturated_queues_shed_503_with_retry_after() {
    let _serial = heavy_guard();
    let (gate_tx, gate_rx) = std::sync::mpsc::channel();
    let coord = Arc::new(Coordinator::start_with_capacity(
        move || GateBackend {
            geometry: Geometry::asic(),
            gate: gate_rx,
        },
        BatchConfig {
            max_batch: 1,
            max_wait: Duration::ZERO,
        },
        2,
    ));
    let state = ServerState::new(Arc::clone(&coord));
    let cfg = ServerConfig {
        addr: "127.0.0.1:0".to_string(),
        http_workers: 2,
        read_timeout: Duration::from_secs(2),
        ..ServerConfig::default()
    };
    let server = HttpServer::start(&cfg, Arc::clone(&state)).expect("bind");
    let addr = server.local_addr();

    // Wedge the worker inside classify, so the capacity-2 queue cannot
    // drain while the HTTP batch lands on it.
    let wedged = coord.submit(BoolImage::blank());
    std::thread::sleep(Duration::from_millis(50));

    let images = random_images(55, 8);
    let refs: Vec<&BoolImage> = images.iter().collect();
    let mut conn = connect(addr);
    let t0 = Instant::now();
    let resp = roundtrip(&mut conn, "POST", "/v1/classify", &classify_body(None, &refs));
    assert_eq!(resp.status, 503, "{}", String::from_utf8_lossy(&resp.body));
    assert_eq!(resp.header("retry-after"), Some("1"));
    let err = convcotm::server::proto::parse_error_body(&resp.body).expect("uniform envelope");
    assert_eq!(err.code, "overloaded");
    assert_eq!(err.retry_after_ms, Some(1000));
    assert!(t0.elapsed() < Duration::from_secs(2), "shedding must not block the HTTP worker");

    // /metrics (registry-less mode) reports the shed; /admin/models 409s.
    let resp = roundtrip(&mut conn, "GET", "/metrics", b"");
    assert_eq!(resp.status, 200);
    let m = body_json(&resp);
    let shed = m.get("http").and_then(|h| h.get("shed_503")).and_then(Json::as_f64);
    assert_eq!(shed, Some(1.0));
    let resp = roundtrip(&mut conn, "POST", "/admin/models", b"m = x.cctm\n");
    assert_eq!(resp.status, 409, "{}", String::from_utf8_lossy(&resp.body));

    // Release the wedge: the direct request plus the two the server's 503
    // path left in the queue (their receivers are dropped — the evaluator
    // completes them into closed channels without issue).
    for _ in 0..3 {
        gate_tx.send(()).ok();
    }
    wedged.recv().unwrap().unwrap();
    drop(gate_tx);
    drop(server);
    drop(state);
    if let Ok(coord) = Arc::try_unwrap(coord) {
        coord.shutdown();
    }
}

/// Acceptance: `POST /admin/shutdown` answers `{"draining":true}` with
/// `Connection: close`, then the server stops accepting, finishes
/// in-flight work and joins — and the pool underneath drains every
/// accepted request.
#[test]
fn admin_shutdown_drains_cleanly() {
    let _serial = heavy_guard();
    let model = random_model(61, 4);
    let (server, state, coord) = start_pool_server(
        ModelRegistry::single("m", model.clone()),
        2,
        1024,
        Duration::from_millis(300),
    );
    let addr = server.local_addr();
    let mut conn = connect(addr);
    let images = random_images(62, 6);
    for img in &images {
        let resp = roundtrip(&mut conn, "POST", "/v1/classify", &classify_body(None, &[img]));
        assert_eq!(resp.status, 200);
    }
    let resp = roundtrip(&mut conn, "POST", "/admin/shutdown", b"");
    assert_eq!(resp.status, 200);
    assert_eq!(body_json(&resp).get("draining").and_then(Json::as_bool), Some(true));
    assert_eq!(resp.header("connection"), Some("close"));
    // The server closes this connection after the drain response.
    assert!(conn.read_response(&Limits::default()).map(|r| r.is_none()).unwrap_or(true));
    let t0 = Instant::now();
    let snap = drain(server, state, coord);
    assert!(t0.elapsed() < Duration::from_secs(5), "drain hung for {:?}", t0.elapsed());
    assert_eq!(snap.requests, 6);
    assert_eq!(snap.errors, 0);
}

/// Routing + malformed input over real sockets: 404 on unknown paths,
/// 405 + Allow on wrong methods, 400 on garbage (with the connection
/// closed), 413 on an oversized declared body, 408 on a mid-request
/// stall (slow-loris), and healthz liveness fields.
#[test]
fn routing_and_malformed_inputs_map_to_4xx_over_sockets() {
    let _serial = heavy_guard();
    let (server, state, coord) = start_pool_server(
        ModelRegistry::single("m", random_model(71, 4)),
        1,
        256,
        Duration::from_millis(250),
    );
    let addr = server.local_addr();

    let mut conn = connect(addr);
    let resp = roundtrip(&mut conn, "GET", "/healthz", b"");
    assert_eq!(resp.status, 200);
    let v = body_json(&resp);
    assert_eq!(v.get("status").and_then(Json::as_str), Some("ok"));
    assert_eq!(v.get("shards").and_then(Json::as_f64), Some(1.0));
    let resp = roundtrip(&mut conn, "GET", "/nope", b"");
    assert_eq!(resp.status, 404);
    let resp = roundtrip(&mut conn, "POST", "/metrics", b"");
    assert_eq!(resp.status, 405);
    assert_eq!(resp.header("allow"), Some("GET"));
    let resp = roundtrip(&mut conn, "POST", "/v1/classify", b"{\"images\":17}");
    assert_eq!(resp.status, 400);

    // Raw garbage: 400 and the connection is closed.
    let mut conn = connect(addr);
    use std::io::Write as _;
    conn.get_mut().write_all(b"NOT HTTP AT ALL\r\n\r\n").unwrap();
    let resp = conn
        .read_response(&Limits::default())
        .expect("a 400 response")
        .expect("a response before close");
    assert_eq!(resp.status, 400);
    assert_eq!(resp.header("connection"), Some("close"));
    assert!(conn.read_response(&Limits::default()).map(|r| r.is_none()).unwrap_or(true));

    // Declared-oversize body: 413 before any body byte is read.
    let mut conn = connect(addr);
    conn.get_mut()
        .write_all(b"POST /v1/classify HTTP/1.1\r\ncontent-length: 999999999999\r\n\r\n")
        .unwrap();
    let resp = conn
        .read_response(&Limits::default())
        .unwrap()
        .expect("a 413 response");
    assert_eq!(resp.status, 413);

    // Slow-loris: a partial request line, then silence — the server
    // answers 408 within its read timeout and drops the connection.
    let mut conn = connect(addr);
    conn.get_mut().write_all(b"POST /v1/cl").unwrap();
    let resp = conn
        .read_response(&Limits::default())
        .expect("a 408 response")
        .expect("a response before close");
    assert_eq!(resp.status, 408);
    assert_eq!(state.stats.read_timeouts.load(Ordering::Relaxed), 1);

    let snap = drain(server, state, coord);
    assert_eq!(snap.requests, 0, "no classify traffic reached the pool");
}
