//! Experiment-level regression tests: the paper's headline numbers and
//! qualitative claims, pinned as assertions (the table/figure benches print
//! the full artifacts; these tests keep them true under refactoring).

use convcotm::asic::{Accelerator, ChipConfig, CycleReport, LATENCY_CYCLES, PERIOD_CYCLES};
use convcotm::coordinator::SysProc;
use convcotm::data::{booleanize_split, SynthFamily};
use convcotm::energy::scaleup::{estimate, paper_specialists, ScaleUpAssumptions};
use convcotm::energy::scaling::scale_asic;
use convcotm::energy::{EnergyModel, OperatingPoint, SYSTEM_PERIOD_CYCLES_27M8};
use convcotm::tm::{Engine, Params, Trainer};

fn reference_report() -> CycleReport {
    let dataset = SynthFamily::Digits.generate(200, 48, 77);
    let train = booleanize_split(&dataset.train, dataset.booleanizer);
    let test = booleanize_split(&dataset.test, dataset.booleanizer);
    let mut trainer = Trainer::new(Params::asic(), 77);
    for e in 0..3 {
        trainer.epoch(&train, e);
    }
    let model = trainer.export();
    let mut acc = Accelerator::new(Params::asic(), ChipConfig::default());
    acc.load_model(&model);
    let mut total = CycleReport::default();
    for (i, (img, _)) in test.iter().enumerate() {
        total.accumulate(&acc.classify(img, None, i > 0).unwrap().report);
    }
    let n = test.len() as u64;
    let mut avg = total;
    avg.phases = convcotm::asic::fsm::PhaseCycles::standard();
    avg.phases.transfer = 0;
    for v in [
        &mut avg.window_dff_clocks,
        &mut avg.clause_dff_clocks,
        &mut avg.sum_pipe_dff_clocks,
        &mut avg.image_buffer_dff_clocks,
        &mut avg.control_dff_clocks,
        &mut avg.model_dff_clocks,
        &mut avg.clause_comb_toggles,
        &mut avg.clause_evaluations,
        &mut avg.adder_ops,
    ] {
        *v /= n;
    }
    avg
}

#[test]
fn headline_epc_8_6_nj() {
    // Table II / abstract: 8.6 nJ per classification at 0.82 V, 27.8 MHz.
    let em = EnergyModel::default();
    let r = reference_report();
    let epc = em.epc(&r, OperatingPoint::FAST_0V82, SYSTEM_PERIOD_CYCLES_27M8);
    assert!(
        (epc - 8.6e-9).abs() / 8.6e-9 < 0.12,
        "EPC {:.2} nJ vs paper 8.6 nJ",
        epc * 1e9
    );
}

#[test]
fn headline_rate_and_latency() {
    let sp = SysProc;
    assert!((sp.classification_rate(27.8e6) - 60.3e3).abs() < 300.0);
    assert!((sp.single_image_latency(27.8e6) - 25.4e-6).abs() < 0.3e-6);
    assert_eq!(PERIOD_CYCLES, 372);
    assert_eq!(LATENCY_CYCLES, 471);
}

#[test]
fn accuracy_ordering_matches_paper() {
    // Paper: MNIST (97.42) > FMNIST (84.54) > KMNIST (82.55). The synthetic
    // substitutes must reproduce the ordering (easiest → hardest).
    let mut accs = Vec::new();
    for family in [SynthFamily::Digits, SynthFamily::Fashion, SynthFamily::Kana] {
        let dataset = family.generate(800, 120, 31);
        let train = booleanize_split(&dataset.train, dataset.booleanizer);
        let test = booleanize_split(&dataset.test, dataset.booleanizer);
        let mut trainer = Trainer::new(Params::asic(), 31);
        for e in 0..6 {
            trainer.epoch(&train, e);
        }
        accs.push(Engine::new().accuracy(&trainer.export(), &test));
    }
    assert!(
        accs[0] > accs[2],
        "digits ({:.3}) must beat kana ({:.3})",
        accs[0],
        accs[2]
    );
    // At this reduced training budget the bar is lower than the standard
    // fixture (which reaches 98.8/93.6/91.0% — see EXPERIMENTS.md); what
    // matters here is that every family is learnable and ordered.
    assert!(accs.iter().all(|&a| a > 0.5), "all families learnable: {accs:?}");
}

#[test]
fn model_sparsity_is_high_like_paper() {
    // §VI-A: 88% of TA actions are exclude in the paper's MNIST model.
    let dataset = SynthFamily::Digits.generate(600, 0, 13);
    let train = booleanize_split(&dataset.train, dataset.booleanizer);
    let mut trainer = Trainer::new(Params::asic(), 13);
    for e in 0..5 {
        trainer.epoch(&train, e);
    }
    let frac = trainer.export().exclude_fraction();
    assert!(
        frac > 0.70,
        "trained TM models are highly sparse (paper: 88%), got {frac:.3}"
    );
}

#[test]
fn section_6a_28nm_estimates() {
    let est = scale_asic(&Params::asic(), 10, 0.52e-3, 60.3e3);
    assert!((est.area_target_mm2 - 0.27).abs() < 0.02);
    assert!((est.epc_j - 4.3e-9).abs() < 0.3e-9);
}

#[test]
fn table3_scaleup_estimates() {
    let est = estimate(&paper_specialists(), &ScaleUpAssumptions::default());
    assert!((est.rate_fps - 3440.0).abs() / 3440.0 < 0.03);
    assert!((est.epc_65nm_j - 0.9e-6).abs() < 0.05e-6);
    assert_eq!(est.total_model_bytes, 130_000);
}

#[test]
fn energy_claims_gating_and_csrf() {
    // §V: gating ≈60%, CSRF <1%.
    let em = EnergyModel::default();
    let dataset = SynthFamily::Digits.generate(200, 32, 7);
    let train = booleanize_split(&dataset.train, dataset.booleanizer);
    let test = booleanize_split(&dataset.test, dataset.booleanizer);
    let mut trainer = Trainer::new(Params::asic(), 7);
    for e in 0..3 {
        trainer.epoch(&train, e);
    }
    let model = trainer.export();
    let run = |cfg: ChipConfig| {
        let mut acc = Accelerator::new(Params::asic(), cfg);
        acc.load_model(&model);
        let mut total = CycleReport::default();
        for (i, (img, _)) in test.iter().enumerate() {
            total.accumulate(&acc.classify(img, None, i > 0).unwrap().report);
        }
        let n = test.len() as u64;
        let mut avg = total;
        avg.phases = convcotm::asic::fsm::PhaseCycles::standard();
        avg.phases.transfer = 0;
        for v in [
            &mut avg.window_dff_clocks,
            &mut avg.clause_dff_clocks,
            &mut avg.sum_pipe_dff_clocks,
            &mut avg.image_buffer_dff_clocks,
            &mut avg.control_dff_clocks,
            &mut avg.model_dff_clocks,
            &mut avg.clause_comb_toggles,
            &mut avg.clause_evaluations,
            &mut avg.adder_ops,
        ] {
            *v /= n;
        }
        em.power(&avg, OperatingPoint::FAST_1V2, SYSTEM_PERIOD_CYCLES_27M8)
    };
    let base = run(ChipConfig::default());
    let ungated = run(ChipConfig { csrf: true, clock_gating: false });
    let no_csrf = run(ChipConfig { csrf: false, clock_gating: true });
    let gating_saving = 1.0 - base / ungated;
    let csrf_saving = 1.0 - base / no_csrf;
    assert!((0.50..0.70).contains(&gating_saving), "gating saving {gating_saving:.3}");
    assert!((0.0..0.01).contains(&csrf_saving), "csrf saving {csrf_saving:.4}");
}
