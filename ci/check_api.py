#!/usr/bin/env python3
"""Gate: API.md must match the code's declared API surface.

The route table (``ROUTES`` in rust/src/server/mod.rs) and the error-code
registry (``ERROR_CODES`` in rust/src/server/http.rs) are the single
source of truth for the v1 HTTP surface. API.md documents both for
humans. This script parses all three and fails the lint job on any
drift, in either direction:

- every route must appear in API.md as a ``### METHOD /path`` heading,
  and every such heading must correspond to a route;
- every declared alias must appear under its route's heading as a
  ``Deprecated alias: `/old/path`.`` line, and vice versa;
- every error code must appear in API.md's error table as a
  ``| `code` | status | ...`` row with the same status, and every table
  row must correspond to a declared code.

Run from anywhere: paths are resolved relative to the repo root.
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
MOD_RS = ROOT / "rust" / "src" / "server" / "mod.rs"
HTTP_RS = ROOT / "rust" / "src" / "server" / "http.rs"
API_MD = ROOT / "API.md"


def _const_block(source: str, name: str, path: Path) -> str:
    """The text between ``pub const NAME`` and the closing ``];``."""
    m = re.search(rf"pub const {name}\b.*?=\s*&\[(.*?)\n\];", source, re.DOTALL)
    if not m:
        sys.exit(f"check_api: cannot find `pub const {name}` in {path}")
    return m.group(1)


def routes_from_code() -> dict[tuple[str, str], list[str]]:
    """{(method, path): [aliases]} from the ROUTES declaration."""
    block = _const_block(MOD_RS.read_text(), "ROUTES", MOD_RS)
    routes: dict[tuple[str, str], list[str]] = {}
    for entry in re.finditer(
        r'method:\s*"([A-Z]+)",\s*path:\s*"([^"]+)",\s*aliases:\s*&\[([^\]]*)\]',
        block,
    ):
        method, path, raw_aliases = entry.groups()
        aliases = re.findall(r'"([^"]+)"', raw_aliases)
        routes[(method, path)] = aliases
    if not routes:
        sys.exit(f"check_api: parsed zero routes from {MOD_RS}")
    return routes


def error_codes_from_code() -> dict[str, int]:
    """{code: status} from the ERROR_CODES declaration."""
    block = _const_block(HTTP_RS.read_text(), "ERROR_CODES", HTTP_RS)
    codes = {m.group(1): int(m.group(2)) for m in re.finditer(r'\("(\w+)",\s*(\d+),', block)}
    if not codes:
        sys.exit(f"check_api: parsed zero error codes from {HTTP_RS}")
    return codes


def api_md_surface() -> tuple[dict[tuple[str, str], list[str]], dict[str, int]]:
    """(routes-with-aliases, error-code table) as documented in API.md."""
    if not API_MD.exists():
        sys.exit(f"check_api: {API_MD} does not exist")
    routes: dict[tuple[str, str], list[str]] = {}
    codes: dict[str, int] = {}
    current: tuple[str, str] | None = None
    for line in API_MD.read_text().splitlines():
        heading = re.match(r"^### ([A-Z]+) (/\S+)\s*$", line)
        if heading:
            current = (heading.group(1), heading.group(2))
            routes[current] = []
            continue
        alias = re.match(r"^Deprecated alias: `(/\S+)`\.?\s*$", line)
        if alias:
            if current is None:
                sys.exit(f"check_api: alias line outside any endpoint heading: {line!r}")
            routes[current].append(alias.group(1))
            continue
        row = re.match(r"^\|\s*`(\w+)`\s*\|\s*(\d+)\s*\|", line)
        if row:
            codes[row.group(1)] = int(row.group(2))
    return routes, codes


def main() -> int:
    code_routes = routes_from_code()
    code_errors = error_codes_from_code()
    doc_routes, doc_errors = api_md_surface()
    problems: list[str] = []

    for key in sorted(set(code_routes) | set(doc_routes)):
        method, path = key
        if key not in doc_routes:
            problems.append(f"route {method} {path} is in ROUTES but has no heading in API.md")
        elif key not in code_routes:
            problems.append(f"API.md documents {method} {path}, which is not in ROUTES")
        elif sorted(code_routes[key]) != sorted(doc_routes[key]):
            problems.append(
                f"alias mismatch for {method} {path}: "
                f"code={sorted(code_routes[key])} doc={sorted(doc_routes[key])}"
            )

    for code in sorted(set(code_errors) | set(doc_errors)):
        if code not in doc_errors:
            problems.append(f"error code `{code}` is in ERROR_CODES but not in API.md's table")
        elif code not in code_errors:
            problems.append(f"API.md's table lists `{code}`, which is not in ERROR_CODES")
        elif code_errors[code] != doc_errors[code]:
            problems.append(
                f"status mismatch for `{code}`: code says {code_errors[code]}, "
                f"API.md says {doc_errors[code]}"
            )

    if problems:
        print("check_api: API.md and the code's API surface have drifted:", file=sys.stderr)
        for p in problems:
            print(f"  - {p}", file=sys.stderr)
        return 1
    print(
        f"check_api: OK — {len(code_routes)} routes "
        f"({sum(len(a) for a in code_routes.values())} aliases), "
        f"{len(code_errors)} error codes match API.md"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
