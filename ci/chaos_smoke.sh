#!/usr/bin/env bash
# Chaos smoke for the supervised serving stack (CI `chaos-smoke` job,
# DESIGN.md §12).
#
# Trains a 1-epoch model, boots `serve --listen` (release binary) with a
# FIXED deterministic fault plan — every 400th evaluation unit panics —
# then drives sustained keep-alive traffic through the load-client
# example. Asserts the process survives its own injected panics: the
# load client completes with zero untyped failures (panicked requests
# surface as 503 + Retry-After and are absorbed by its jittered
# backoff), /metrics shows the panics happened and the workers were
# respawned, no shard is dead, and the server still drains cleanly.
#
# Usage: ci/chaos_smoke.sh [path/to/convcotm [path/to/load_client]]
set -euo pipefail

BIN=${1:-rust/target/release/convcotm}
LOAD=${2:-rust/target/release/examples/load_client}
FAULT_PLAN='seed=42,eval_panic=n400'
TMP=$(mktemp -d)
SERVE_PID=""
cleanup() {
  if [[ -n "$SERVE_PID" ]] && kill -0 "$SERVE_PID" 2>/dev/null; then
    kill "$SERVE_PID" 2>/dev/null || true
  fi
  rm -rf "$TMP"
}
trap cleanup EXIT

echo "== train a quick model =="
BENCH_TRAIN_JSON="$TMP/bench_train.json" \
  "$BIN" train --dataset mnist --epochs 1 --n-train 300 --n-test 100 \
  --out "$TMP/m.cctm"

echo "== start the front door with an armed fault plan =="
"$BIN" serve --model "chaos=$TMP/m.cctm" --listen 127.0.0.1:0 \
  --shards 2 --http-workers 2 --deadline-ms 5000 \
  --fault-plan "$FAULT_PLAN" >"$TMP/serve.log" 2>&1 &
SERVE_PID=$!

ADDR=""
for _ in $(seq 1 100); do
  ADDR=$(sed -n 's#.*listening on http://\([0-9.]*:[0-9]*\).*#\1#p' "$TMP/serve.log" | head -1)
  [[ -n "$ADDR" ]] && break
  if ! kill -0 "$SERVE_PID" 2>/dev/null; then
    echo "server exited before listening:" >&2
    cat "$TMP/serve.log" >&2
    exit 1
  fi
  sleep 0.1
done
if [[ -z "$ADDR" ]]; then
  echo "server never reported its listen address:" >&2
  cat "$TMP/serve.log" >&2
  exit 1
fi
grep -q "fault injection ARMED: seed=42" "$TMP/serve.log" || {
  echo "server did not announce the armed fault plan:" >&2
  cat "$TMP/serve.log" >&2
  exit 1
}
echo "front door at $ADDR under plan '$FAULT_PLAN'"

echo "== drive traffic through the injected panics =="
# 4 connections x 200 requests x batch 4 = 3200 evaluation units ->
# ~8 injected panics. load_client exits non-zero on any *untyped*
# failure, so its success is the no-lost-requests assertion.
"$LOAD" --addr "$ADDR" --connections 4 --requests 200 --batch 4 \
  --model chaos | tee "$TMP/load.log"
grep -Eq '[1-9][0-9]* shed 503' "$TMP/load.log" || {
  echo "no request ever saw the typed 503 — did the panics happen?" >&2
  exit 1
}

echo "== supervision counters =="
python3 - "$ADDR" <<'PY'
import json
import sys
import urllib.request

addr = sys.argv[1]
base = f"http://{addr}"

def get(path):
    with urllib.request.urlopen(base + path, timeout=10) as resp:
        return resp.status, json.loads(resp.read())

status, m = get("/metrics")
assert status == 200, m
assert m["shard_panics"] >= 1, f"injected panics not counted: {m}"
assert m["respawns"] >= 1, f"panicked workers were never respawned: {m}"
assert all(h != "dead" for h in m["shard_health"]), f"shard died: {m}"
assert m["errors"] >= 1, f"panicked units not accounted as errors: {m}"
assert m["requests"] >= 1, m

status, health = get("/healthz")
assert status == 200, health
assert health["status"] in ("ok", "degraded"), health
print(f"survived: {m['shard_panics']} panic(s), {m['respawns']} respawn(s), "
      f"health={m['shard_health']}, {m['requests']} unit(s) served, "
      f"{m['errors']} typed failure(s)")

req = urllib.request.Request(base + "/admin/shutdown", data=b"", method="POST")
with urllib.request.urlopen(req, timeout=10) as resp:
    out = json.loads(resp.read())
    assert resp.status == 200 and out["draining"] is True, out
print("drain requested")
PY

echo "== wait for the drained exit =="
for _ in $(seq 1 100); do
  kill -0 "$SERVE_PID" 2>/dev/null || break
  sleep 0.1
done
if kill -0 "$SERVE_PID" 2>/dev/null; then
  echo "server did not exit after /admin/shutdown:" >&2
  cat "$TMP/serve.log" >&2
  exit 1
fi
wait "$SERVE_PID"
SERVE_PID=""
grep -q "drained after" "$TMP/serve.log" || {
  echo "missing drained summary:" >&2
  cat "$TMP/serve.log" >&2
  exit 1
}
echo "chaos smoke: OK"
