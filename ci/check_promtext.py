#!/usr/bin/env python3
"""Prometheus text-exposition linter for the hand-rolled renderer.

The server's `/v1/metrics?format=prometheus` output comes from
`rust/src/obs/promtext.rs`, which renders the text format by hand (the
repo is std-only — no client library). This linter is the contract that
keeps that renderer honest: CI scrapes a live server in
`ci/http_smoke.sh` and pipes the body through here, and
`tests/observability.rs` asserts the same invariants from Rust.

Checked (text format v0.0.4):
  - every sample belongs to a family declared with `# HELP` and `# TYPE`
    *before* its first sample;
  - metric names match `[a-zA-Z_:][a-zA-Z0-9_:]*`;
  - declared types are one of counter | gauge | histogram;
  - counter family names end in `_total`;
  - histogram families expose `_bucket`/`_sum`/`_count` series whose
    `le` edges parse, ascend, and carry cumulative non-decreasing
    counts, with a `+Inf` bucket equal to `_count`;
  - every sample value parses as a float;
  - no duplicate (name, labels) series.

Usage: check_promtext.py [FILE]   (reads stdin when FILE is omitted)
"""

import math
import re
import sys

NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>.*)\})?\s+(?P<value>\S+)(?:\s+\S+)?$"
)
TYPES = {"counter", "gauge", "histogram"}
HIST_SUFFIXES = ("_bucket", "_sum", "_count")


def parse_value(raw):
    if raw == "+Inf":
        return math.inf
    if raw == "-Inf":
        return -math.inf
    return float(raw)


def family_of(name, types):
    """Map a sample name to its declared family (histogram suffix-aware)."""
    if name in types:
        return name
    for suffix in HIST_SUFFIXES:
        base = name.removesuffix(suffix)
        if base != name and types.get(base) == "histogram":
            return base
    return None


def lint(text):
    errors = []
    types = {}  # family -> declared type
    helped = set()
    seen_series = set()  # (name, labels) duplicates
    # histogram family -> {series-key -> [(le, count)]} and sums/counts
    hist_buckets = {}
    hist_scalars = {}

    for lineno, line in enumerate(text.splitlines(), 1):
        def err(msg):
            errors.append(f"line {lineno}: {msg} | {line}")

        if not line.strip():
            continue
        if line.startswith("# HELP "):
            parts = line.split(" ", 3)
            if len(parts) < 4 or not NAME_RE.match(parts[2]):
                err("malformed HELP line")
                continue
            helped.add(parts[2])
            continue
        if line.startswith("# TYPE "):
            parts = line.split(" ")
            if len(parts) != 4 or not NAME_RE.match(parts[2]):
                err("malformed TYPE line")
                continue
            name, kind = parts[2], parts[3]
            if kind not in TYPES:
                err(f"type '{kind}' not in {sorted(TYPES)}")
                continue
            if name in types:
                err(f"duplicate TYPE declaration for {name}")
            if name not in helped:
                err(f"TYPE for {name} without a preceding HELP")
            if kind == "counter" and not name.endswith("_total"):
                err(f"counter {name} must end in _total")
            types[name] = kind
            continue
        if line.startswith("#"):
            continue  # free-form comment

        m = SAMPLE_RE.match(line)
        if not m:
            err("unparseable sample line")
            continue
        name, labels, raw = m.group("name"), m.group("labels") or "", m.group("value")
        try:
            value = parse_value(raw)
        except ValueError:
            err(f"value '{raw}' is not a float")
            continue
        family = family_of(name, types)
        if family is None:
            err(f"sample {name} has no preceding HELP/TYPE declaration")
            continue
        series = (name, labels)
        if series in seen_series:
            err(f"duplicate series {name}{{{labels}}}")
        seen_series.add(series)

        if types[family] == "histogram":
            scalars = hist_scalars.setdefault(family, {})
            if name == family + "_bucket":
                pairs = [p for p in labels.split(",") if p and not p.startswith("le=")]
                le = None
                for part in labels.split(","):
                    if part.startswith('le="') and part.endswith('"'):
                        le = part[4:-1]
                if le is None:
                    err("histogram bucket without an le label")
                    continue
                try:
                    edge = parse_value(le)
                except ValueError:
                    err(f"le edge '{le}' is not a float")
                    continue
                key = ",".join(pairs)
                hist_buckets.setdefault(family, {}).setdefault(key, []).append(
                    (lineno, edge, value)
                )
            elif name == family + "_sum":
                scalars[("sum", labels)] = value
            elif name == family + "_count":
                scalars[("count", labels)] = value
            elif name == family:
                err(f"histogram {family} exposes a bare sample")

    # Cross-line histogram invariants.
    for family, by_series in hist_buckets.items():
        for key, buckets in by_series.items():
            where = f"{family}{{{key}}}" if key else family
            edges = [e for _, e, _ in buckets]
            if edges != sorted(edges):
                errors.append(f"{where}: le edges are not ascending: {edges}")
            counts = [c for _, _, c in buckets]
            if any(later < earlier for earlier, later in zip(counts, counts[1:])):
                errors.append(f"{where}: bucket counts are not cumulative: {counts}")
            if not edges or not math.isinf(edges[-1]):
                errors.append(f"{where}: missing +Inf bucket")
                continue
            count = hist_scalars.get(family, {}).get(("count", key))
            if count is None:
                errors.append(f"{where}: no matching {family}_count series")
            elif counts[-1] != count:
                errors.append(
                    f"{where}: +Inf bucket {counts[-1]} != _count {count}"
                )
    for family, kind in types.items():
        if kind == "histogram" and family not in hist_buckets:
            errors.append(f"{family}: declared histogram has no _bucket series")

    return errors, len(seen_series)


def main(argv):
    if len(argv) > 2:
        print(__doc__)
        return 2
    if len(argv) == 2:
        with open(argv[1], encoding="utf-8") as f:
            text = f.read()
    else:
        text = sys.stdin.read()
    errors, n_series = lint(text)
    if errors:
        print(f"promtext lint: {len(errors)} error(s)", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        return 1
    if n_series == 0:
        print("promtext lint: no samples found", file=sys.stderr)
        return 1
    print(f"promtext lint: OK ({n_series} series)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
