#!/usr/bin/env python3
"""Bench-regression guard for the CI perf gate.

Diffs a fresh BENCH_hotpath.json (written by `cargo bench --bench
hotpath_microbench`, quick mode in CI) against the committed
BENCH_baseline.json and fails the build when any path regresses by more
than the threshold (default 25% throughput), or when a path whose
baseline holds the zero-alloc invariant (0.0 allocs/img) starts
allocating. A markdown comparison table is written to
$GITHUB_STEP_SUMMARY (when set) and always printed to stdout.

Rows are matched by their "path" label after normalizing
machine-dependent parts (thread counts, batch sizes), so the same
baseline works across runners with different core counts. Rows present
on only one side are reported but never fail the gate — bench coverage
may grow PR over PR.

Refreshing the baseline (DESIGN.md §8): download the BENCH_hotpath
artifact from a green run of the target runner class and commit it as
rust/BENCH_baseline.json. The committed baseline is intentionally
conservative until refreshed from a real CI artifact.

Usage: check_bench.py BASELINE.json FRESH.json [--threshold=0.25]
"""

import json
import os
import re
import sys


# Scalar keys whose baseline value is a hard floor for the fresh run (not
# threshold-scaled): the blocked evaluator must stay a clear multiple of
# the scalar compiled plan or it has no reason to exist.
FLOOR_KEYS = ("block_speedup_vs_plan",)

# Scalar keys whose baseline value is a hard ceiling for the fresh run:
# the disarmed observability hooks must stay free (≤1% of request time)
# or they are not allowed to live on the hot path.
CEILING_KEYS = ("trace_overhead_pct",)

# Normalized paths whose fresh allocs_per_img must be exactly 0.0. The
# blocked hot path's zero-alloc invariant is absolute — 0.4 allocs/img
# would pass the generic >0.5 alloc gate while still meaning a per-block
# allocation crept in.
STRICT_ZERO_ALLOC = {
    "native engine (blocked B=32)",
    "NativeBackend batch=N (blocked)",
}


def normalize(label: str) -> str:
    """Strip machine-dependent details so labels match across runners.

    Only the *plural* thread count is machine-dependent (the parallel
    NativeBackend row uses the runner's core count); "(1 thread)" is a
    distinct, stable serial row and must not collapse into it.
    """
    label = re.sub(r"\d+ threads", "N threads", label)
    label = re.sub(r"batch=\d+", "batch=N", label)
    return label


def load_rows(path: str):
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    rows = {}
    for row in data.get("rows", []):
        rows[normalize(row["path"])] = row
    return rows, data


def main(argv):
    args = [a for a in argv[1:] if not a.startswith("--")]
    if len(args) != 2:
        print(__doc__)
        return 2
    threshold = 0.25
    for a in argv[1:]:
        if a.startswith("--threshold"):
            if "=" not in a:
                print("expected --threshold=FRACTION (e.g. --threshold=0.25)", file=sys.stderr)
                return 2
            threshold = float(a.split("=", 1)[1])
    baseline_path, fresh_path = args
    baseline, baseline_doc = load_rows(baseline_path)
    fresh, fresh_doc = load_rows(fresh_path)

    lines = [
        "## Hot-path bench vs committed baseline",
        "",
        f"threshold: fail below {100 * (1 - threshold):.0f}% of baseline throughput "
        f"(quick={fresh_doc.get('quick')})",
        "",
        "| Path | Baseline img/s | Fresh img/s | Δ | Allocs/img | Status |",
        "|---|---|---|---|---|---|",
    ]
    failures = []
    for label in sorted(set(baseline) | set(fresh)):
        b, f = baseline.get(label), fresh.get(label)
        if b is None:
            lines.append(
                f"| {label} | — | {f['img_per_s']:.0f} | — | "
                f"{f.get('allocs_per_img')} | NEW |"
            )
            continue
        if f is None:
            lines.append(f"| {label} | {b['img_per_s']:.0f} | — | — | — | MISSING |")
            continue
        ratio = f["img_per_s"] / b["img_per_s"] if b["img_per_s"] else float("inf")
        status = "OK"
        if ratio < 1 - threshold:
            status = "REGRESSED"
            failures.append(
                f"{label}: {f['img_per_s']:.0f} img/s is "
                f"{100 * (1 - ratio):.0f}% below baseline {b['img_per_s']:.0f}"
            )
        # The zero-alloc invariant is a separate, absolute gate: a path
        # measured at 0 allocs/img in the baseline must stay there.
        b_allocs, f_allocs = b.get("allocs_per_img"), f.get("allocs_per_img")
        if b_allocs == 0.0 and f_allocs is not None and f_allocs > 0.5:
            status = "ALLOC-REGRESSED"
            failures.append(
                f"{label}: {f_allocs:.1f} allocs/img on a zero-alloc baseline path"
            )
        lines.append(
            f"| {label} | {b['img_per_s']:.0f} | {f['img_per_s']:.0f} | "
            f"{100 * (ratio - 1):+.0f}% | {f_allocs} | {status} |"
        )
    # The blocked rows must measure 0.0 allocs/img exactly, whether the
    # row is NEW or matched against the baseline.
    for label in sorted(STRICT_ZERO_ALLOC):
        if label not in fresh:
            failures.append(f"{label}: zero-alloc row missing from the fresh run")
            continue
        allocs = fresh[label].get("allocs_per_img")
        if allocs != 0.0:
            failures.append(
                f"{label}: allocs_per_img must be exactly 0.0, measured {allocs}"
            )
    for key, unit in (
        ("plan_speedup_vs_early_exit", "×"),
        ("block_speedup_vs_plan", "×"),
        ("pool_speedup_4v1_shards", "×"),
        ("http_speedup_4v1_shards", "×"),
        ("http_overhead_us", " µs"),
        ("trace_overhead_pct", "%"),
        ("train_speedup_4v1", "×"),
    ):
        value = fresh_doc.get(key)
        if isinstance(value, (int, float)):
            lines.append("")
            lines.append(f"`{key}` = {value:.2f}{unit}")
    for key in FLOOR_KEYS:
        b_val, f_val = baseline_doc.get(key), fresh_doc.get(key)
        if not isinstance(b_val, (int, float)):
            continue
        if not isinstance(f_val, (int, float)):
            failures.append(f"{key}: missing from the fresh run (baseline floor {b_val:.2f})")
        elif f_val < b_val:
            failures.append(f"{key}: {f_val:.2f} below the baseline floor {b_val:.2f}")
    for key in CEILING_KEYS:
        b_val, f_val = baseline_doc.get(key), fresh_doc.get(key)
        if not isinstance(b_val, (int, float)):
            continue
        if not isinstance(f_val, (int, float)):
            failures.append(f"{key}: missing from the fresh run (baseline ceiling {b_val:.2f})")
        elif f_val > b_val:
            failures.append(f"{key}: {f_val:.2f} above the baseline ceiling {b_val:.2f}")

    report = "\n".join(lines) + "\n"
    print(report)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a", encoding="utf-8") as f:
            f.write(report)

    if failures:
        print("BENCH REGRESSION GATE FAILED:", file=sys.stderr)
        for failure in failures:
            print(f"  - {failure}", file=sys.stderr)
        print(
            "If intentional (e.g. a deliberate trade-off), refresh "
            "rust/BENCH_baseline.json from the run's artifact and justify "
            "the change in the PR.",
            file=sys.stderr,
        )
        return 1
    print("bench regression gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
