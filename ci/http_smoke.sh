#!/usr/bin/env bash
# End-to-end smoke test for the HTTP front door (CI `http-smoke` job).
#
# Phase 1 (single server): trains a 1-epoch model, starts
# `serve --listen 127.0.0.1:0` (release binary) in the background, then
# over real sockets: POSTs one image and asserts 200 + a well-formed
# classify response, asserts GET /v1/models and /metrics accounting,
# asserts the deprecated alias paths still answer (plus `Deprecation:
# true`), drains via the alias POST /admin/shutdown and verifies the
# process exits cleanly with its final drained summary.
#
# Phase 2 (route tier): starts two `serve` replicas and one `route`
# process fronting them, drives sequential classify load through the
# router, SIGKILLs the replica that is actually serving mid-load, and
# asserts zero dropped and zero non-enveloped responses across the
# failover, a degraded /healthz, and a clean router drain.
#
# Usage: ci/http_smoke.sh [path/to/convcotm]
set -euo pipefail

BIN=${1:-rust/target/release/convcotm}
TMP=$(mktemp -d)
PIDS=()
cleanup() {
  for pid in "${PIDS[@]:-}"; do
    if [[ -n "$pid" ]] && kill -0 "$pid" 2>/dev/null; then
      kill "$pid" 2>/dev/null || true
    fi
  done
  rm -rf "$TMP"
}
trap cleanup EXIT

# Scrape "<verb> on http://ADDR" from a background process's log.
wait_for_addr() { # logfile pid verb
  local log=$1 pid=$2 verb=$3 addr=""
  for _ in $(seq 1 100); do
    addr=$(sed -n "s#.*$verb on http://\([0-9.]*:[0-9]*\).*#\1#p" "$log" | head -1)
    [[ -n "$addr" ]] && break
    if ! kill -0 "$pid" 2>/dev/null; then
      echo "process exited before listening:" >&2
      cat "$log" >&2
      exit 1
    fi
    sleep 0.1
  done
  if [[ -z "$addr" ]]; then
    echo "process never reported its address:" >&2
    cat "$log" >&2
    exit 1
  fi
  echo "$addr"
}

echo "== train a quick model =="
BENCH_TRAIN_JSON="$TMP/bench_train.json" \
  "$BIN" train --dataset mnist --epochs 1 --n-train 300 --n-test 100 \
  --out "$TMP/m.cctm"

echo "== phase 1: single front door =="
"$BIN" serve --model "smoke=$TMP/m.cctm" --listen 127.0.0.1:0 \
  --shards 2 --http-workers 2 >"$TMP/serve.log" 2>&1 &
SERVE_PID=$!
PIDS+=("$SERVE_PID")
ADDR=$(wait_for_addr "$TMP/serve.log" "$SERVE_PID" listening)
echo "front door at $ADDR"

python3 - "$ADDR" <<'PY'
import json
import sys
import urllib.error
import urllib.request

addr = sys.argv[1]
base = f"http://{addr}"

def call(path, payload=None, method=None):
    data = None
    if payload is not None:
        data = payload if isinstance(payload, bytes) else json.dumps(payload).encode()
    req = urllib.request.Request(base + path, data=data, method=method)
    with urllib.request.urlopen(req, timeout=10) as resp:
        return resp.status, dict(resp.headers), json.loads(resp.read())

status, _, health = call("/healthz")
assert status == 200 and health["status"] == "ok", health
assert "smoke" in health["models"], health

# The versioned inventory endpoint.
status, headers, models = call("/v1/models")
assert status == 200, models
assert [m["name"] for m in models["models"]] == ["smoke"], models
assert models["models"][0]["version"] == 1, models
assert "deprecation" not in {k.lower() for k in headers}, headers

# One image: a blob of bright pixels, booleanized server-side.
pixels = [0] * 784
for y in range(10, 18):
    for x in range(10, 18):
        pixels[y * 28 + x] = 200
status, _, out = call("/v1/classify", {"model": "smoke", "image": {"pixels": pixels}})
assert status == 200, out
assert out["count"] == 1, out
(result,) = out["results"]
assert 0 <= result["class"] <= 9, out
assert result["model_version"] == 1, out
assert len(result["class_sums"]) == 10, out
print(f"classified as {result['class']} (model v{result['model_version']})")

# A non-2xx answer must carry the uniform envelope with a stable code.
try:
    call("/v1/classify", {"model": "ghost", "image": {"pixels": pixels}})
    raise AssertionError("classify for an unknown model must fail")
except urllib.error.HTTPError as e:
    body = json.loads(e.read())
    assert e.code == 404 and body["error"]["code"] == "model_not_found", body

status, _, metrics = call("/metrics")
assert status == 200, metrics
assert metrics["requests"] >= 1, metrics
assert metrics["http"]["responses_2xx"] >= 2, metrics
print(f"metrics: {metrics['requests']} pool request(s), "
      f"{metrics['http']['requests']} http request(s)")

# The deprecated alias answers canonically, flagged with Deprecation.
status, headers, out = call("/admin/shutdown", b"")
assert status == 200 and out["draining"] is True, out
assert headers.get("Deprecation", headers.get("deprecation")) == "true", headers
print("drain requested via the deprecated alias (Deprecation: true)")
PY

echo "== phase 1: wait for the drained exit =="
for _ in $(seq 1 100); do
  kill -0 "$SERVE_PID" 2>/dev/null || break
  sleep 0.1
done
if kill -0 "$SERVE_PID" 2>/dev/null; then
  echo "server did not exit after /admin/shutdown:" >&2
  cat "$TMP/serve.log" >&2
  exit 1
fi
wait "$SERVE_PID"
grep -q "drained after" "$TMP/serve.log" || {
  echo "missing drained summary:" >&2
  cat "$TMP/serve.log" >&2
  exit 1
}
echo "phase 1: OK"

echo "== phase 2: route tier (2 replicas + router, kill one mid-load) =="
"$BIN" serve --model "smoke=$TMP/m.cctm" --listen 127.0.0.1:0 \
  --shards 1 --http-workers 2 >"$TMP/replica1.log" 2>&1 &
R1_PID=$!
PIDS+=("$R1_PID")
"$BIN" serve --model "smoke=$TMP/m.cctm" --listen 127.0.0.1:0 \
  --shards 1 --http-workers 2 >"$TMP/replica2.log" 2>&1 &
R2_PID=$!
PIDS+=("$R2_PID")
R1_ADDR=$(wait_for_addr "$TMP/replica1.log" "$R1_PID" listening)
R2_ADDR=$(wait_for_addr "$TMP/replica2.log" "$R2_PID" listening)

"$BIN" route --listen 127.0.0.1:0 --replica "$R1_ADDR" --replica "$R2_ADDR" \
  --health-interval-ms 100 --http-workers 2 >"$TMP/route.log" 2>&1 &
ROUTE_PID=$!
PIDS+=("$ROUTE_PID")
ROUTE_ADDR=$(wait_for_addr "$TMP/route.log" "$ROUTE_PID" routing)
echo "router at $ROUTE_ADDR over $R1_ADDR + $R2_ADDR"

python3 - "$ROUTE_ADDR" "$R1_ADDR=$R1_PID" "$R2_ADDR=$R2_PID" <<'PY'
import json
import os
import signal
import sys
import urllib.error
import urllib.request

addr = sys.argv[1]
base = f"http://{addr}"
pid_of = dict(kv.rsplit("=", 1) for kv in sys.argv[2:])

def call(path, payload=None):
    data = None
    if payload is not None:
        data = payload if isinstance(payload, bytes) else json.dumps(payload).encode()
    req = urllib.request.Request(base + path, data=data)
    with urllib.request.urlopen(req, timeout=10) as resp:
        return resp.status, json.loads(resp.read())

pixels = [0] * 784
for y in range(10, 18):
    for x in range(10, 18):
        pixels[y * 28 + x] = 200
body = {"model": "smoke", "image": {"pixels": pixels}}

# Both replicas mirror the model: the union is still one entry.
status, models = call("/v1/models")
assert status == 200, models
assert [m["name"] for m in models["models"]] == ["smoke"], models
assert len(models["replicas"]) == 2, models

TOTAL, KILL_AT = 300, 100
outcomes = []  # (status, code-or-None) per request — nothing is dropped
killed = None
for i in range(TOTAL):
    try:
        status, out = call("/v1/classify", body)
        assert out["count"] == 1, out
        outcomes.append((status, None))
    except urllib.error.HTTPError as e:
        # Every failure must be the uniform envelope with a stable code.
        err = json.loads(e.read())["error"]
        outcomes.append((e.code, err["code"]))
    if i + 1 == KILL_AT:
        # Kill whichever replica is actually serving (the rendezvous
        # owner): the one the router reports forwards on.
        _, metrics = call("/metrics")
        owner = max(metrics["router"], key=lambda a: metrics["router"][a]["forwarded"])
        killed = owner
        os.kill(int(pid_of[owner]), signal.SIGKILL)
        print(f"killed owner replica {owner} after {KILL_AT} requests")

assert len(outcomes) == TOTAL, f"dropped {TOTAL - len(outcomes)} responses"
ok = sum(1 for s, _ in outcomes if s == 200)
errors = [(s, c) for s, c in outcomes if s != 200]
for s, c in errors:
    assert c is not None, f"HTTP {s} without an envelope code"
    assert c in ("replica_unavailable", "overloaded", "shard_panicked"), (s, c)
assert ok >= TOTAL - 20, f"only {ok}/{TOTAL} succeeded across the failover: {errors}"
tail = outcomes[-50:]
assert all(s == 200 for s, _ in tail), f"traffic did not settle on the survivor: {tail}"
print(f"failover: {ok}/{TOTAL} ok, {len(errors)} enveloped error(s), 0 dropped")

status, health = call("/healthz")
assert status == 200 and health["status"] == "degraded", health
assert health["role"] == "router", health

status, out = call("/v1/admin/shutdown", b"")
assert status == 200 and out["draining"] is True, out
print("router drain requested")
PY

echo "== phase 2: wait for the drained router exit =="
for _ in $(seq 1 100); do
  kill -0 "$ROUTE_PID" 2>/dev/null || break
  sleep 0.1
done
if kill -0 "$ROUTE_PID" 2>/dev/null; then
  echo "router did not exit after /v1/admin/shutdown:" >&2
  cat "$TMP/route.log" >&2
  exit 1
fi
wait "$ROUTE_PID" || true
grep -q "drained after .* forwarded request" "$TMP/route.log" || {
  echo "missing router drained summary:" >&2
  cat "$TMP/route.log" >&2
  exit 1
}
echo "http smoke: OK"
