#!/usr/bin/env bash
# End-to-end smoke test for the HTTP front door (CI `http-smoke` job).
#
# Phase 1 (single server): trains a 1-epoch model, starts
# `serve --listen 127.0.0.1:0` (release binary) in the background, then
# over real sockets: POSTs one image and asserts 200 + a well-formed
# classify response, asserts GET /v1/models and /v1/metrics accounting,
# asserts the X-Request-Id contract (supplied ids echoed, absent ids
# minted as 32-hex), scrapes `/v1/metrics?format=prometheus` and lints
# it with ci/check_promtext.py, asserts /v1/debug/slow holds span
# trees, asserts the deprecated alias paths still answer (plus
# `Deprecation: true`), drains via the alias POST /admin/shutdown and
# verifies the process exits cleanly with its final drained summary.
#
# Phase 2 (route tier): starts two `serve` replicas and one `route`
# process fronting them, asserts a request id round-trips router →
# replica (span trees on both tiers via /v1/debug/slow), drives
# sequential classify load through the router, SIGKILLs the replica
# that is actually serving mid-load, and asserts zero dropped and zero
# non-enveloped responses across the failover, a lint-clean router
# Prometheus scrape, a degraded /healthz, and a clean router drain.
#
# Usage: ci/http_smoke.sh [path/to/convcotm]
set -euo pipefail

BIN=${1:-rust/target/release/convcotm}
TMP=$(mktemp -d)
PIDS=()
cleanup() {
  for pid in "${PIDS[@]:-}"; do
    if [[ -n "$pid" ]] && kill -0 "$pid" 2>/dev/null; then
      kill "$pid" 2>/dev/null || true
    fi
  done
  rm -rf "$TMP"
}
trap cleanup EXIT

# Scrape "<verb> on http://ADDR" from a background process's log.
wait_for_addr() { # logfile pid verb
  local log=$1 pid=$2 verb=$3 addr=""
  for _ in $(seq 1 100); do
    addr=$(sed -n "s#.*$verb on http://\([0-9.]*:[0-9]*\).*#\1#p" "$log" | head -1)
    [[ -n "$addr" ]] && break
    if ! kill -0 "$pid" 2>/dev/null; then
      echo "process exited before listening:" >&2
      cat "$log" >&2
      exit 1
    fi
    sleep 0.1
  done
  if [[ -z "$addr" ]]; then
    echo "process never reported its address:" >&2
    cat "$log" >&2
    exit 1
  fi
  echo "$addr"
}

echo "== train a quick model =="
BENCH_TRAIN_JSON="$TMP/bench_train.json" \
  "$BIN" train --dataset mnist --epochs 1 --n-train 300 --n-test 100 \
  --out "$TMP/m.cctm"

echo "== phase 1: single front door =="
"$BIN" serve --model "smoke=$TMP/m.cctm" --listen 127.0.0.1:0 \
  --shards 2 --http-workers 2 >"$TMP/serve.log" 2>&1 &
SERVE_PID=$!
PIDS+=("$SERVE_PID")
ADDR=$(wait_for_addr "$TMP/serve.log" "$SERVE_PID" listening)
echo "front door at $ADDR"

python3 - "$ADDR" "$TMP" <<'PY'
import json
import os
import sys
import urllib.error
import urllib.request

addr = sys.argv[1]
tmp = sys.argv[2]
base = f"http://{addr}"

def call(path, payload=None, method=None):
    data = None
    if payload is not None:
        data = payload if isinstance(payload, bytes) else json.dumps(payload).encode()
    req = urllib.request.Request(base + path, data=data, method=method)
    with urllib.request.urlopen(req, timeout=10) as resp:
        return resp.status, dict(resp.headers), json.loads(resp.read())

status, _, health = call("/healthz")
assert status == 200 and health["status"] == "ok", health
assert "smoke" in health["models"], health

# The versioned inventory endpoint.
status, headers, models = call("/v1/models")
assert status == 200, models
assert [m["name"] for m in models["models"]] == ["smoke"], models
assert models["models"][0]["version"] == 1, models
assert "deprecation" not in {k.lower() for k in headers}, headers

# One image: a blob of bright pixels, booleanized server-side.
pixels = [0] * 784
for y in range(10, 18):
    for x in range(10, 18):
        pixels[y * 28 + x] = 200
status, _, out = call("/v1/classify", {"model": "smoke", "image": {"pixels": pixels}})
assert status == 200, out
assert out["count"] == 1, out
(result,) = out["results"]
assert 0 <= result["class"] <= 9, out
assert result["model_version"] == 1, out
assert len(result["class_sums"]) == 10, out
print(f"classified as {result['class']} (model v{result['model_version']})")

# A non-2xx answer must carry the uniform envelope with a stable code.
try:
    call("/v1/classify", {"model": "ghost", "image": {"pixels": pixels}})
    raise AssertionError("classify for an unknown model must fail")
except urllib.error.HTTPError as e:
    body = json.loads(e.read())
    assert e.code == 404 and body["error"]["code"] == "model_not_found", body

status, headers, metrics = call("/v1/metrics")
assert status == 200, metrics
assert "deprecation" not in {k.lower() for k in headers}, headers
assert metrics["requests"] >= 1, metrics
assert metrics["http"]["responses_2xx"] >= 2, metrics
assert metrics["latency_hist"]["count"] >= 1, metrics["latency_hist"]
print(f"metrics: {metrics['requests']} pool request(s), "
      f"{metrics['http']['requests']} http request(s)")

# Request-id contract: a supplied X-Request-Id is echoed verbatim; an
# absent one is replaced by a minted 32-char lowercase-hex id.
req = urllib.request.Request(base + "/healthz", headers={"X-Request-Id": "smoke-req-1"})
with urllib.request.urlopen(req, timeout=10) as resp:
    assert resp.headers.get("X-Request-Id") == "smoke-req-1", dict(resp.headers)
with urllib.request.urlopen(base + "/healthz", timeout=10) as resp:
    minted = resp.headers.get("X-Request-Id")
assert minted and len(minted) == 32, minted
assert all(c in "0123456789abcdef" for c in minted), minted
print(f"request ids: supplied id echoed, absent id minted ({minted})")

# The Prometheus rendering of the same snapshot, linted after this block.
req = urllib.request.Request(base + "/v1/metrics?format=prometheus")
with urllib.request.urlopen(req, timeout=10) as resp:
    ctype = resp.headers.get("Content-Type", "")
    prom = resp.read().decode()
assert ctype.startswith("text/plain; version=0.0.4"), ctype
assert "# TYPE convcotm_requests_total counter" in prom, prom[:400]
assert "convcotm_request_latency_seconds_bucket" in prom, prom[:400]
with open(os.path.join(tmp, "prom_serve.txt"), "w") as f:
    f.write(prom)

# The slow-request ring: `serve` runs with the default --trace-slow-us 0,
# so every request competes and the classify span tree must be present.
status, _, slow = call("/v1/debug/slow")
assert status == 200 and slow["armed"] is True, slow
stages = {s["stage"] for e in slow["slow"] for s in e["stages"]}
assert {"parse", "eval"} <= stages, slow
print(f"debug/slow: {slow['count']} trace(s), stages {sorted(stages)}")

# The deprecated alias answers canonically, flagged with Deprecation.
status, headers, out = call("/admin/shutdown", b"")
assert status == 200 and out["draining"] is True, out
assert headers.get("Deprecation", headers.get("deprecation")) == "true", headers
print("drain requested via the deprecated alias (Deprecation: true)")
PY
python3 ci/check_promtext.py "$TMP/prom_serve.txt"

echo "== phase 1: wait for the drained exit =="
for _ in $(seq 1 100); do
  kill -0 "$SERVE_PID" 2>/dev/null || break
  sleep 0.1
done
if kill -0 "$SERVE_PID" 2>/dev/null; then
  echo "server did not exit after /admin/shutdown:" >&2
  cat "$TMP/serve.log" >&2
  exit 1
fi
wait "$SERVE_PID"
grep -q "drained after" "$TMP/serve.log" || {
  echo "missing drained summary:" >&2
  cat "$TMP/serve.log" >&2
  exit 1
}
echo "phase 1: OK"

echo "== phase 2: route tier (2 replicas + router, kill one mid-load) =="
"$BIN" serve --model "smoke=$TMP/m.cctm" --listen 127.0.0.1:0 \
  --shards 1 --http-workers 2 >"$TMP/replica1.log" 2>&1 &
R1_PID=$!
PIDS+=("$R1_PID")
"$BIN" serve --model "smoke=$TMP/m.cctm" --listen 127.0.0.1:0 \
  --shards 1 --http-workers 2 >"$TMP/replica2.log" 2>&1 &
R2_PID=$!
PIDS+=("$R2_PID")
R1_ADDR=$(wait_for_addr "$TMP/replica1.log" "$R1_PID" listening)
R2_ADDR=$(wait_for_addr "$TMP/replica2.log" "$R2_PID" listening)

"$BIN" route --listen 127.0.0.1:0 --replica "$R1_ADDR" --replica "$R2_ADDR" \
  --health-interval-ms 100 --http-workers 2 >"$TMP/route.log" 2>&1 &
ROUTE_PID=$!
PIDS+=("$ROUTE_PID")
ROUTE_ADDR=$(wait_for_addr "$TMP/route.log" "$ROUTE_PID" routing)
echo "router at $ROUTE_ADDR over $R1_ADDR + $R2_ADDR"

python3 - "$ROUTE_ADDR" "$TMP" "$R1_ADDR=$R1_PID" "$R2_ADDR=$R2_PID" <<'PY'
import json
import os
import signal
import sys
import urllib.error
import urllib.request

addr = sys.argv[1]
tmp = sys.argv[2]
base = f"http://{addr}"
pid_of = dict(kv.rsplit("=", 1) for kv in sys.argv[3:])

def call(path, payload=None):
    data = None
    if payload is not None:
        data = payload if isinstance(payload, bytes) else json.dumps(payload).encode()
    req = urllib.request.Request(base + path, data=data)
    with urllib.request.urlopen(req, timeout=10) as resp:
        return resp.status, json.loads(resp.read())

pixels = [0] * 784
for y in range(10, 18):
    for x in range(10, 18):
        pixels[y * 28 + x] = 200
body = {"model": "smoke", "image": {"pixels": pixels}}

# Both replicas mirror the model: the union is still one entry.
status, models = call("/v1/models")
assert status == 200, models
assert [m["name"] for m in models["models"]] == ["smoke"], models
assert len(models["replicas"]) == 2, models

# A request id round-trips client → router → replica: the router echoes
# it, and both tiers' slow rings hold a span tree under that id.
req = urllib.request.Request(
    base + "/v1/classify",
    data=json.dumps(body).encode(),
    headers={"X-Request-Id": "smoke-trace-e2e"},
)
with urllib.request.urlopen(req, timeout=10) as resp:
    assert resp.status == 200, resp.status
    assert resp.headers.get("X-Request-Id") == "smoke-trace-e2e", dict(resp.headers)
status, slow = call("/v1/debug/slow")
assert status == 200 and slow["armed"] is True, slow
mine = [e for e in slow["slow"] if e["request_id"] == "smoke-trace-e2e"]
assert mine, [e["request_id"] for e in slow["slow"]]
router_stages = {s["stage"] for e in mine for s in e["stages"]}
assert "forward" in router_stages, mine
assert len(slow["replicas"]) == 2, sorted(slow["replicas"])
replica_stages = {
    s["stage"]
    for rep in slow["replicas"].values()
    for e in rep.get("slow", [])
    if e["request_id"] == "smoke-trace-e2e"
    for s in e["stages"]
}
assert {"parse", "eval"} <= replica_stages, slow["replicas"]
print("span tree: router forward + replica parse/eval under one request id")

TOTAL, KILL_AT = 300, 100
outcomes = []  # (status, code-or-None) per request — nothing is dropped
killed = None
for i in range(TOTAL):
    try:
        status, out = call("/v1/classify", body)
        assert out["count"] == 1, out
        outcomes.append((status, None))
    except urllib.error.HTTPError as e:
        # Every failure must be the uniform envelope with a stable code.
        err = json.loads(e.read())["error"]
        outcomes.append((e.code, err["code"]))
    if i + 1 == KILL_AT:
        # Kill whichever replica is actually serving (the rendezvous
        # owner): the one the router reports forwards on.
        _, metrics = call("/v1/metrics")
        owner = max(metrics["router"], key=lambda a: metrics["router"][a]["forwarded"])
        killed = owner
        os.kill(int(pid_of[owner]), signal.SIGKILL)
        print(f"killed owner replica {owner} after {KILL_AT} requests")

assert len(outcomes) == TOTAL, f"dropped {TOTAL - len(outcomes)} responses"
ok = sum(1 for s, _ in outcomes if s == 200)
errors = [(s, c) for s, c in outcomes if s != 200]
for s, c in errors:
    assert c is not None, f"HTTP {s} without an envelope code"
    assert c in ("replica_unavailable", "overloaded", "shard_panicked"), (s, c)
assert ok >= TOTAL - 20, f"only {ok}/{TOTAL} succeeded across the failover: {errors}"
tail = outcomes[-50:]
assert all(s == 200 for s, _ in tail), f"traffic did not settle on the survivor: {tail}"
print(f"failover: {ok}/{TOTAL} ok, {len(errors)} enveloped error(s), 0 dropped")

# Fleet metrics after the failover: percentiles derived from the merged
# histograms, plus the Prometheus rendering (linted after this block).
# (The killed owner's counts died with it; only the survivor reports.)
_, metrics = call("/v1/metrics")
assert metrics["latency_hist"]["count"] > 0, metrics["latency_hist"]
assert metrics["latency_p50_us"] > 0, metrics
assert "debug" in metrics, sorted(metrics)
req = urllib.request.Request(base + "/v1/metrics?format=prometheus")
with urllib.request.urlopen(req, timeout=10) as resp:
    ctype = resp.headers.get("Content-Type", "")
    prom = resp.read().decode()
assert ctype.startswith("text/plain; version=0.0.4"), ctype
assert "convcotm_request_latency_seconds_bucket" in prom, prom[:400]
with open(os.path.join(tmp, "prom_route.txt"), "w") as f:
    f.write(prom)

status, health = call("/healthz")
assert status == 200 and health["status"] == "degraded", health
assert health["role"] == "router", health

status, out = call("/v1/admin/shutdown", b"")
assert status == 200 and out["draining"] is True, out
print("router drain requested")
PY
python3 ci/check_promtext.py "$TMP/prom_route.txt"

echo "== phase 2: wait for the drained router exit =="
for _ in $(seq 1 100); do
  kill -0 "$ROUTE_PID" 2>/dev/null || break
  sleep 0.1
done
if kill -0 "$ROUTE_PID" 2>/dev/null; then
  echo "router did not exit after /v1/admin/shutdown:" >&2
  cat "$TMP/route.log" >&2
  exit 1
fi
wait "$ROUTE_PID" || true
grep -q "drained after .* forwarded request" "$TMP/route.log" || {
  echo "missing router drained summary:" >&2
  cat "$TMP/route.log" >&2
  exit 1
}
echo "http smoke: OK"
