#!/usr/bin/env bash
# End-to-end smoke test for the HTTP front door (CI `http-smoke` job).
#
# Trains a 1-epoch model, starts `serve --listen 127.0.0.1:0` (release
# binary) in the background, then over real sockets: POSTs one image and
# asserts 200 + a well-formed classify response, asserts GET /metrics
# counted the request, drains via POST /admin/shutdown and verifies the
# process exits cleanly with its final drained summary.
#
# Usage: ci/http_smoke.sh [path/to/convcotm]
set -euo pipefail

BIN=${1:-rust/target/release/convcotm}
TMP=$(mktemp -d)
SERVE_PID=""
cleanup() {
  if [[ -n "$SERVE_PID" ]] && kill -0 "$SERVE_PID" 2>/dev/null; then
    kill "$SERVE_PID" 2>/dev/null || true
  fi
  rm -rf "$TMP"
}
trap cleanup EXIT

echo "== train a quick model =="
BENCH_TRAIN_JSON="$TMP/bench_train.json" \
  "$BIN" train --dataset mnist --epochs 1 --n-train 300 --n-test 100 \
  --out "$TMP/m.cctm"

echo "== start the front door =="
"$BIN" serve --model "smoke=$TMP/m.cctm" --listen 127.0.0.1:0 \
  --shards 2 --http-workers 2 >"$TMP/serve.log" 2>&1 &
SERVE_PID=$!

ADDR=""
for _ in $(seq 1 100); do
  ADDR=$(sed -n 's#.*listening on http://\([0-9.]*:[0-9]*\).*#\1#p' "$TMP/serve.log" | head -1)
  [[ -n "$ADDR" ]] && break
  if ! kill -0 "$SERVE_PID" 2>/dev/null; then
    echo "server exited before listening:" >&2
    cat "$TMP/serve.log" >&2
    exit 1
  fi
  sleep 0.1
done
if [[ -z "$ADDR" ]]; then
  echo "server never reported its listen address:" >&2
  cat "$TMP/serve.log" >&2
  exit 1
fi
echo "front door at $ADDR"

echo "== classify + metrics + drain over the wire =="
python3 - "$ADDR" <<'PY'
import json
import sys
import urllib.request

addr = sys.argv[1]
base = f"http://{addr}"

def post(path, payload):
    data = payload if isinstance(payload, bytes) else json.dumps(payload).encode()
    req = urllib.request.Request(base + path, data=data, method="POST")
    with urllib.request.urlopen(req, timeout=10) as resp:
        return resp.status, json.loads(resp.read())

def get(path):
    with urllib.request.urlopen(base + path, timeout=10) as resp:
        return resp.status, json.loads(resp.read())

status, health = get("/healthz")
assert status == 200 and health["status"] == "ok", health
assert "smoke" in health["models"], health

# One image: a blob of bright pixels, booleanized server-side.
pixels = [0] * 784
for y in range(10, 18):
    for x in range(10, 18):
        pixels[y * 28 + x] = 200
status, out = post("/v1/classify", {"model": "smoke", "image": {"pixels": pixels}})
assert status == 200, out
assert out["count"] == 1, out
(result,) = out["results"]
assert 0 <= result["class"] <= 9, out
assert result["model_version"] == 1, out
assert len(result["class_sums"]) == 10, out
print(f"classified as {result['class']} (model v{result['model_version']})")

status, metrics = get("/metrics")
assert status == 200, metrics
assert metrics["requests"] >= 1, metrics
assert metrics["http"]["responses_2xx"] >= 2, metrics
print(f"metrics: {metrics['requests']} pool request(s), "
      f"{metrics['http']['requests']} http request(s)")

status, out = post("/admin/shutdown", b"")
assert status == 200 and out["draining"] is True, out
print("drain requested")
PY

echo "== wait for the drained exit =="
for _ in $(seq 1 100); do
  kill -0 "$SERVE_PID" 2>/dev/null || break
  sleep 0.1
done
if kill -0 "$SERVE_PID" 2>/dev/null; then
  echo "server did not exit after /admin/shutdown:" >&2
  cat "$TMP/serve.log" >&2
  exit 1
fi
wait "$SERVE_PID"
SERVE_PID=""
grep -q "drained after" "$TMP/serve.log" || {
  echo "missing drained summary:" >&2
  cat "$TMP/serve.log" >&2
  exit 1
}
echo "http smoke: OK"
